"""Looped-vs-fabric wall clock for the paper's headline grids.

Times the Fig. 1 Pareto grid (7 budget ceilings x 20 seeds) two ways:

  * looped — the pre-fabric protocol: one ``evaluate.run`` call per
    ceiling (per-condition jitted dispatch, host loop over conditions);
  * fabric — ``sweep.run_grid``: the flattened (condition x seed) grid as
    ONE compiled call, sharded across available devices.

Both paths are timed cold (first call, includes compile) and warm
(steady-state dispatch), and the fabric's per-condition results are
asserted bit-identical to the looped baseline before any timing is
reported. Results land in ``benchmarks/results/sweep.json``.

``--devices N`` forces N CPU placeholder devices (dryrun.py's
``xla_force_host_platform_device_count`` convention) so the sharded path
is exercised on machines without accelerators; it must be parsed before
jax is imported, hence the top-of-module argv peek. ``--smoke`` shrinks
the environment and grid for CI.
"""
from __future__ import annotations

import sys

from benchmarks._devices import apply_devices_flag

apply_devices_flag(sys.argv)  # must precede any jax import

import argparse
import time

import jax
import numpy as np

from benchmarks.bench_pareto import BUDGET_SWEEP
from benchmarks.common import (
    SEEDS, benchmark, emit, run_condition, run_condition_grid,
)
from repro.core import simulator, sweep


def _time(fn, repeats: int):
    """(cold_s, warm_s): first call includes compile; warm is best-of."""
    t0 = time.perf_counter()
    fn()
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        warm = min(warm, time.perf_counter() - t0)
    return cold, warm


def run(env, budgets, seeds, repeats: int):
    rows = []

    def looped():
        return [run_condition("pareto", env, b, seeds=seeds)
                for b in budgets]

    def fabric():
        return run_condition_grid("pareto", env, budgets, seeds=seeds)

    # Equivalence gate before timing: fabric grid == looped, bit-for-bit.
    base = looped()
    grid = fabric()
    for i, res in enumerate(base):
        np.testing.assert_array_equal(grid.condition(i).arms, res.arms)
        np.testing.assert_array_equal(grid.condition(i).rewards, res.rewards)
        np.testing.assert_array_equal(grid.condition(i).costs, res.costs)
        np.testing.assert_array_equal(grid.condition(i).lams, res.lams)
    rows.append(["sweep_equivalence", "bit_identical",
                 f"{len(budgets)}x{len(seeds)} grid"])

    # Cold timings need fresh programs: drop both caches.
    sweep._cached_grid_fn.cache_clear()
    from repro.core import evaluate
    evaluate._cached_run_fn.cache_clear()

    looped_cold, looped_warm = _time(looped, repeats)
    fabric_cold, fabric_warm = _time(fabric, repeats)
    n_dev = len(jax.devices())
    grid_sz = f"{len(budgets)}x{len(seeds)}x{env.n}"
    rows.append(["sweep_looped_s", f"{looped_warm:.3f}",
                 f"cold={looped_cold:.3f};grid={grid_sz}"])
    rows.append(["sweep_fabric_s", f"{fabric_warm:.3f}",
                 f"cold={fabric_cold:.3f};devices={n_dev}"])
    rows.append(["sweep_speedup", f"{looped_warm / fabric_warm:.2f}x",
                 f"cold {looped_cold / fabric_cold:.2f}x"])
    emit(rows, ["name", "value", "derived"], "sweep")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced environment + grid (CI)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU placeholder devices (before jax init)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    if args.smoke:
        b = simulator.make_benchmark(
            seed=0, splits={"train": 256, "val": 32, "test": 200})
        rows = run(b.test, budgets=list(BUDGET_SWEEP[:3]),
                   seeds=tuple(range(4)), repeats=max(1, args.repeats - 2))
    else:
        rows = run(benchmark().test, budgets=list(BUDGET_SWEEP),
                   seeds=SEEDS, repeats=args.repeats)
    for r in rows:
        assert r, r
    return rows


if __name__ == "__main__":
    main()
