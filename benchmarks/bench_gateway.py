"""Serving gateway benchmark (DESIGN.md §13).

Gates, then times, the decoupled select/learn gateway:

  * bit-identity gate — the gateway at publish cadence 1 must reproduce
    the synchronous select/update fold exactly (arms + final state);
  * sustained decisions/sec through route_block + enqueue + learn_tick
    (the ROADMAP >=100k decisions/s acceptance line);
  * select-plane p95 isolation — per-block route latency with a learner
    thread continuously applying feedback and publishing snapshots must
    stay in family with the uncontended baseline (the point of the
    decoupled planes: learning off the request path);
  * zero-retrace gate — router.TRACE_COUNT frozen across publishes,
    control retunes and learner contention.

``--smoke`` runs reduced reps (the CI gateway-smoke job) and emits the
same ``benchmarks/results/gateway.json`` artifact.
"""
from __future__ import annotations

import sys

from benchmarks._devices import apply_devices_flag

apply_devices_flag(sys.argv)  # must precede any jax import

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from tests.trace_guard import assert_traces
from repro.core import router
from repro.core.types import RouterConfig, init_state
from repro.serving.gateway import RouterGateway

CFG = RouterConfig(d=26, max_arms=4)
PRICES = (1e-4, 1e-3, 5.6e-3, 1e9)
ACTIVE = (1, 1, 1, 0)


def _state(seed=0):
    prices = jnp.asarray(PRICES, jnp.float32)
    return init_state(CFG, prices, prices, budget=6.6e-4,
                      active=jnp.asarray(ACTIVE, bool),
                      key=jax.random.PRNGKey(seed))


def _gateway(seed=0):
    return RouterGateway(CFG, _state(seed))


def _blocks(n, B, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, B, CFG.d)).astype(np.float32)
    r = rng.uniform(0.2, 0.9, (n, B)).astype(np.float32)
    c = rng.uniform(1e-5, 1e-3, (n, B)).astype(np.float32)
    return X, r, c


def gate_bit_identity(n_blocks=8, B=64):
    """Gateway at cadence 1 == synchronous fold, bit for bit."""
    X, r, c = _blocks(n_blocks, B, seed=1)
    sel = router.jit_select_batch(CFG.statics)
    upd = router.jit_update_batch(CFG.statics)
    ref = _state()
    ref_arms = []
    for i in range(n_blocks):
        dec, ref = sel(ref, X[i])
        arms = np.asarray(dec.arms)
        ref_arms.append(arms)
        ref = upd(ref, jnp.asarray(arms, jnp.int32), X[i], r[i], c[i])

    gw = _gateway()
    rid = 0
    for i in range(n_blocks):
        ids = list(range(rid, rid + B))
        rid += B
        res = gw.route_block(ids, X[i])
        assert np.array_equal(res.arms, ref_arms[i]), f"block {i} diverged"
        gw.enqueue_feedback(ids, res.arms, r[i], c[i])
        gw.learn_tick()
    for leaf in ("A", "A_inv", "b", "theta", "t", "force_left"):
        a = np.asarray(getattr(gw.live_state, leaf))
        b_ = np.asarray(getattr(ref, leaf))
        assert np.array_equal(a, b_), f"state leaf {leaf} diverged"
    return True


def time_throughput(n_blocks, B, tick_every=4):
    """Sustained decisions/sec: route + enqueue + periodic learner tick,
    end to end (the serve_batch steady state)."""
    X, r, c = _blocks(n_blocks, B, seed=2)
    gw = _gateway()
    # warm the compiled programs off the clock
    res = gw.route_block(list(range(B)), X[0])
    gw.enqueue_feedback(res.request_ids, res.arms, r[0], c[0])
    gw.learn_tick()
    jax.block_until_ready(gw.live_state.theta)

    rid = B
    t0 = time.perf_counter()
    for i in range(n_blocks):
        ids = list(range(rid, rid + B))
        rid += B
        res = gw.route_block(ids, X[i])
        gw.enqueue_feedback(ids, res.arms, r[i], c[i])
        if (i + 1) % tick_every == 0:
            gw.learn_tick()
    gw.learn_tick()
    jax.block_until_ready(gw.live_state.theta)
    dt = time.perf_counter() - t0
    return n_blocks * B / dt


def time_select_p95(n_blocks, B, contended):
    """Per-decision select-plane latency, with or without a learner
    thread hammering enqueue_feedback + learn_tick concurrently."""
    X, r, c = _blocks(n_blocks, B, seed=3)
    gw = _gateway()
    res = gw.route_block(list(range(B)), X[0])
    gw.enqueue_feedback(res.request_ids, res.arms, r[0], c[0])
    gw.learn_tick()
    jax.block_until_ready(gw.live_state.theta)

    stop = threading.Event()
    feedback: list = []
    flock = threading.Lock()

    def learner():
        while not stop.is_set():
            with flock:
                batch, feedback[:] = feedback[:], []
            for ids, arms, rr, cc in batch:
                gw.enqueue_feedback(ids, arms, rr, cc)
            if batch:
                gw.learn_tick()
            else:
                time.sleep(0)

    th = None
    if contended:
        th = threading.Thread(target=learner)
        th.start()
    lat_us = []
    rid = B
    for i in range(n_blocks):
        ids = list(range(rid, rid + B))
        rid += B
        res = gw.route_block(ids, X[i])
        np.asarray(res.arms)          # materialised before the clock stops
        lat_us.append(res.route_us)
        if contended:
            with flock:
                feedback.append((ids, res.arms, r[i], c[i]))
        else:
            gw.enqueue_feedback(ids, res.arms, r[i], c[i])
    if th is not None:
        stop.set()
        th.join()
    else:
        gw.learn_tick()
    p50 = float(np.percentile(lat_us, 50))
    p95 = float(np.percentile(lat_us, 95))
    return p50, p95, gw.version


def main(smoke: bool = False):
    rows = []
    gate_bit_identity()
    rows.append(["bit_identity_cadence1", "1",
                 "gateway==sync fold over 8 blocks; arms+state leaves"])

    n_thr = 40 if smoke else 400
    n_lat = 60 if smoke else 600
    B = 256

    # everything below must re-enter the two compiled block programs
    time_throughput(4, B)             # warm all paths first

    with assert_traces(router, 0, what="gateway retraced under "
                                       "publishes/contention") as tg:
        dps = time_throughput(n_thr, B)
        rows.append([f"gateway_decisions_per_s_B{B}", f"{dps:.0f}",
                     f"route+enqueue+tick/4; n_blocks={n_thr}; "
                     "acceptance >=100000"])

        p50_b, p95_b, _ = time_select_p95(n_lat, B, contended=False)
        rows.append([f"select_p95_us_B{B}_baseline", f"{p95_b:.2f}",
                     f"p50={p50_b:.2f};per-decision us; no learner ticks"])
        p50_c, p95_c, n_pub = time_select_p95(n_lat, B, contended=True)
        ratio = p95_c / p95_b if p95_b > 0 else float("inf")
        # On a 1-core host the learner's update_batch device compute and
        # the select share the CPU, so the ratio mostly measures core
        # scarcity, not the gateway lock (route_block's critical section
        # is only the async dispatch). Record the core count so readers
        # can tell.
        import os
        cores = len(os.sched_getaffinity(0))
        rows.append([f"select_p95_us_B{B}_contended", f"{p95_c:.2f}",
                     f"p50={p50_c:.2f};publishes={n_pub};"
                     f"p95_ratio_vs_baseline={ratio:.2f};cores={cores}"])

    rows.append(["zero_retraces", "1",
                 f"TRACE_COUNT frozen at {tg.before} across "
                 f"{n_thr + 2 * n_lat} blocks + publishes"])

    emit(rows, ["name", "value", "derived"], "gateway")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced reps (CI gateway-smoke job)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU placeholder devices (before jax init)")
    args = ap.parse_args()
    main(smoke=args.smoke)
