"""Appendix E (Tables 6-9, Fig. 12): reward-signal robustness across
judges.

Three synthetic judges with distinct calibration profiles (the stand-ins
for DeepSeek-R1 / GPT-4.1-mini / Claude-3.7): a shared latent quality per
(prompt, model) plus judge-specific gain, offset, and noise. Checks:
population-level ordering invariance, cross-judge oracle capture, and
cold-start bandit regret replication under each judge.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import TABULA_CFG, benchmark, emit
from repro.core import evaluate

# (gain, offset, extra noise): R1 has the widest margins (paper E.2);
# the supplementary judges compress margins ~15-30% and add per-response
# disagreement noise (Table 8's MAD ~0.075 vs R1).
JUDGES = {
    "r1": (1.00, 0.000, 0.000),
    "gpt41mini": (0.85, 0.045, 0.020),
    "claude37": (0.90, -0.010, 0.025),
}


def judge_views(env, seed=0):
    rng = np.random.default_rng(seed)
    views = {}
    for name, (gain, off, noise) in JUDGES.items():
        mean = env.rewards.mean()
        r = mean + gain * (env.rewards - mean) + off
        r = r + noise * rng.standard_normal(env.rewards.shape)
        views[name] = dataclasses.replace(
            env, rewards=np.clip(r, 0.0, 1.0).astype(np.float32))
    return views


def main(seeds=tuple(range(10))):
    b = benchmark()
    env = b.test
    views = judge_views(env)
    rows = []

    # Table 6: expected reward ordering per judge
    for name, v in views.items():
        means = v.rewards.mean(axis=0)
        order = "".join("<" if means[i] < means[i + 1] else ">"
                        for i in range(2))
        rows.append([f"judge_{name}_means",
                     "/".join(f"{m:.3f}" for m in means),
                     f"ordering_llama_mistral_gemini={order}"])

    # Table 7: cross-judge oracle capture — follow row judge's oracle,
    # evaluate with column judge
    r1_oracle_arms = views["r1"].rewards.argmax(axis=1)
    for name, v in views.items():
        own = v.rewards.max(axis=1).mean()
        got = v.rewards[np.arange(env.n), r1_oracle_arms].mean()
        rows.append([f"cross_oracle_r1_to_{name}", f"{got / own:.3f}",
                     f"own_oracle={own:.4f}"])

    # Fig. 12: cold-start regret reduction vs random, per judge
    for name, v in views.items():
        res = evaluate.run(TABULA_CFG, v, 1.0, seeds=seeds)
        oracle = v.rewards.max(axis=1)
        regret = []
        rnd = []
        for i, s in enumerate(seeds):
            perm = np.random.default_rng(int(s)).permutation(v.n)
            regret.append((oracle[perm] - res.rewards[i]).sum())
            rng = np.random.default_rng(1000 + s)
            arms = rng.integers(0, 3, v.n)
            rnd.append((oracle - v.rewards[np.arange(v.n), arms]).sum())
        red = 1.0 - np.mean(regret) / np.mean(rnd)
        rows.append([f"coldstart_regret_{name}", f"{np.mean(regret):.1f}",
                     f"vs_random={np.mean(rnd):.1f};reduction={red:.0%}"])
    emit(rows, ["name", "value", "derived"], "judges")
    return rows


if __name__ == "__main__":
    main()
