"""Tables 10-11 / §3.5: routing latency microbenchmark.

Eight configurations isolating three factors, as in the paper:
  * production overhead — the full jitted ParetoBandit route+update cycle;
  * Sherman-Morrison vs full inversion (numpy, same route() code path);
  * PCA dimensionality d=26 vs d=385 (raw-dimension baseline).
Plus the Pallas batched-scoring kernel's oracle path and the end-to-end
pipeline (hash-encode + PCA + route).

Absolute numbers are container-CPU specific; the paper's *relative*
claims (SM update advantage, d^2 scaling, sub-% share of inference
latency) are the reproduction targets.

The fused-step section (``--smoke`` or appended to a full run) gates and
times the ``pallas_fused`` megakernel (DESIGN.md §11) against the
looped score-kernel + XLA-update path and records the comparison —
equivalence, zero-retrace, per-B wall clock, ``block_r`` autotune — to
``benchmarks/results/fused_step.json`` (the CI ``fused-step`` job's
artifact).
"""
from __future__ import annotations

import sys

from benchmarks._devices import apply_devices_flag

apply_devices_flag(sys.argv)  # must precede any jax import

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import router
from repro.core import types as types_lib
from repro.core.types import HyperParams, RouterConfig, init_state

N_CYCLES = 2000
WARMUP = 200


def _percentiles(ts):
    return (float(np.percentile(ts, 50) * 1e6),
            float(np.percentile(ts, 95) * 1e6))


# ---------------------------------------------------------------------------
# numpy router variants (algorithmic isolation, same route() math)
# ---------------------------------------------------------------------------

class NumpyRouter:
    """LinUCB with static cost penalty; update strategy selectable."""

    def __init__(self, K, d, mode, alpha=0.05, lambda_c=0.3, seed=0):
        rng = np.random.default_rng(seed)
        self.K, self.d, self.mode = K, d, mode
        self.A = np.stack([np.eye(d) for _ in range(K)])
        self.A_inv = np.stack([np.eye(d) for _ in range(K)])
        self.b = np.zeros((K, d))
        self.theta = np.zeros((K, d))
        self.alpha, self.lambda_c = alpha, lambda_c
        self.c_tilde = np.linspace(0, 0.7, K)

    def route(self, x):
        if self.mode == "per_route_inv":
            self.A_inv = np.linalg.inv(self.A)
        s = self.theta @ x
        for k in range(self.K):
            s[k] += self.alpha * np.sqrt(
                max(x @ (self.A_inv[k] @ x), 0.0))
        s -= self.lambda_c * self.c_tilde
        return int(np.argmax(s))

    def update(self, k, x, r):
        self.A[k] += np.outer(x, x)
        self.b[k] += r * x
        if self.mode == "sm":
            Ax = self.A_inv[k] @ x
            self.A_inv[k] -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        elif self.mode == "cached_inv":
            self.A_inv[k] = np.linalg.inv(self.A[k])
        self.theta[k] = self.A_inv[k] @ self.b[k]


def time_numpy(mode, d, n=N_CYCLES):
    r = NumpyRouter(3, d, mode)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((n + WARMUP, d))
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    t_route, t_upd = [], []
    for i, x in enumerate(xs):
        t0 = time.perf_counter()
        k = r.route(x)
        t1 = time.perf_counter()
        r.update(k, x, 0.8)
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


# ---------------------------------------------------------------------------
# production (jitted JAX) router
# ---------------------------------------------------------------------------

def time_production(d, n=N_CYCLES):
    cfg = RouterConfig(d=d, max_arms=3, hyper=HyperParams(alpha=0.05))
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    upd = jax.jit(lambda s, a, x: router.update(
        cfg, s, a, x, jnp.float32(0.8), jnp.float32(1e-4)))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n + WARMUP, d)), jnp.float32)
    # warmup-compile
    dec, state = sel(state, xs[0])
    state = upd(state, dec.arm, xs[0])
    jax.block_until_ready(state.A)
    t_route, t_upd = [], []
    for i in range(n + WARMUP):
        t0 = time.perf_counter()
        dec, state = sel(state, xs[i])
        dec.arm.block_until_ready()
        t1 = time.perf_counter()
        state = upd(state, dec.arm, xs[i])
        state.theta.block_until_ready()
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


def time_e2e(n=300):
    """hash-encode + PCA + route (the paper's Table 11)."""
    from repro.core.features import fit_pca_whitener, hash_encode
    from repro.data import make_request_stream
    rng = np.random.default_rng(0)
    corpus = [r["prompt"] for r in make_request_stream(400, seed=1)]
    raw = np.stack([hash_encode(p) for p in corpus])
    wh = fit_pca_whitener(raw)
    cfg = RouterConfig(max_arms=3, hyper=HyperParams(alpha=0.05))
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    x = wh(jnp.asarray(hash_encode(corpus[0])))
    dec, state = sel(state, x)
    jax.block_until_ready(dec.arm)
    t_embed, t_pca, t_route, t_total = [], [], [], []
    for i in range(n):
        p = corpus[i % len(corpus)]
        t0 = time.perf_counter()
        raw_v = hash_encode(p)
        t1 = time.perf_counter()
        x = wh(jnp.asarray(raw_v))
        x.block_until_ready()
        t2 = time.perf_counter()
        dec, state = sel(state, x)
        dec.arm.block_until_ready()
        t3 = time.perf_counter()
        t_embed.append(t1 - t0)
        t_pca.append(t2 - t1)
        t_route.append(t3 - t2)
        t_total.append(t3 - t0)
    return t_embed, t_pca, t_route, t_total


def time_pallas_batch(n_requests=4096):
    """Batched UCB scoring kernel throughput (requests/s)."""
    from repro.kernels.linucb_score.ops import linucb_score
    rng = np.random.default_rng(0)
    d, K = 26, 3
    x = jnp.asarray(rng.standard_normal((n_requests, d)), jnp.float32)
    theta = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
    M = rng.standard_normal((K, d, d)) * 0.1
    A = np.einsum("kij,klj->kil", M, M) + np.eye(d)[None]
    ainv = jnp.asarray(np.linalg.inv(A), jnp.float32)
    pen = jnp.asarray([0.0, 0.1, 0.2], jnp.float32)
    infl = jnp.ones((K,), jnp.float32)
    out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return n_requests / dt


# ---------------------------------------------------------------------------
# batched data plane: full select_batch + update_batch cycle per backend
# ---------------------------------------------------------------------------

BATCH_SIZES = (1, 8, 64, 256)
BACKENDS = ("jnp", "pallas", "pallas_fused")


def time_batched_sweep(batch_sizes=BATCH_SIZES, backends=BACKENDS,
                       reps=30, d=26, seed=0):
    """Batched routing throughput: decisions/s and µs/decision for the
    full route+update block cycle, per backend and block size.

    Returns {(backend, B): (us_per_decision, decisions_per_s)}.
    """
    rng = np.random.default_rng(seed)
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3], jnp.float32)
    out = {}
    for bk in backends:
        cfg = RouterConfig(d=d, max_arms=3, backend=bk,
                           hyper=HyperParams(alpha=0.05))

        def cycle(s, X, R, C, cfg=cfg):
            return router.step_batch(cfg, s, X, R, C)

        cycle = jax.jit(cycle)
        for B in batch_sizes:
            state = init_state(cfg, prices, prices, budget=6.6e-4)
            X = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
            R = jnp.asarray(rng.uniform(0.5, 1.0, (B, 3)), jnp.float32)
            C = jnp.asarray(rng.uniform(1e-5, 1e-3, (B, 3)), jnp.float32)
            state, _ = cycle(state, X, R, C)   # compile
            jax.block_until_ready(state.A)
            t0 = time.perf_counter()
            for _ in range(reps):
                state, trace = cycle(state, X, R, C)
            jax.block_until_ready(state.A)
            dt = (time.perf_counter() - t0) / reps
            out[(bk, B)] = (dt / B * 1e6, B / dt)
    return out


def backend_score_divergence(B=256, d=26, K=3, seed=0):
    """Max abs score diff jnp vs Pallas on one block (the ≤1e-4 contract)."""
    from repro.core import backend as backend_lib
    rng = np.random.default_rng(seed)
    cfg = RouterConfig(d=d, max_arms=K, hyper=HyperParams(alpha=0.05))
    theta = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
    M = rng.standard_normal((K, d, d)) * 0.1
    A = np.einsum("kij,klj->kil", M, M) + np.eye(d)[None]
    ainv = jnp.asarray(np.linalg.inv(A), jnp.float32)
    c_tilde = jnp.asarray(np.linspace(0.0, 0.7, K), jnp.float32)
    X = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    dt = jnp.asarray(rng.integers(0, 500, K), jnp.int32)
    return backend_lib.score_divergence(
        cfg, cfg.hyper.as_leaves(), theta, ainv, c_tilde, X, dt,
        jnp.float32(0.7))


# ---------------------------------------------------------------------------
# fused step megakernel: equivalence gates + looped-vs-fused wall clock
# ---------------------------------------------------------------------------


def _rand_block(rng, B, d, K=3):
    X = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    R = jnp.asarray(rng.uniform(0.5, 1.0, (B, K)), jnp.float32)
    C = jnp.asarray(rng.uniform(1e-5, 1e-3, (B, K)), jnp.float32)
    return X, R, C


def _warmed_state(d=26, K=3, blocks=4, seed=0):
    """A state with non-trivial statistics (a few jnp-oracle blocks)."""
    rng = np.random.default_rng(seed)
    cfg = RouterConfig(d=d, max_arms=K, hyper=HyperParams(alpha=0.05))
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3], jnp.float32)
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    for _ in range(blocks):
        X, R, C = _rand_block(rng, 16, d, K)
        state, _ = router.step_batch(cfg, state, X, R, C)
    return state, rng


def fused_step_equivalence(B=256, d=26, seed=0):
    """Fused megakernel vs jnp oracle on one warmed closed-loop block:
    (arms identical?, max stats abs diff, max pacer abs diff)."""
    state, rng = _warmed_state(d=d, seed=seed)
    X, R, C = _rand_block(rng, B, d)
    outs = {}
    for bk in ("jnp", "pallas_fused"):
        cfg = RouterConfig(d=d, max_arms=3, backend=bk,
                           hyper=HyperParams(alpha=0.05))
        outs[bk] = router.step_batch(cfg, state, X, R, C)
    (sj, tj), (sf, tf) = outs["jnp"], outs["pallas_fused"]
    arms_ok = bool(jnp.all(tj[0] == tf[0]))
    stats = max(
        float(jnp.max(jnp.abs(getattr(sj, n) - getattr(sf, n))))
        for n in ("A", "A_inv", "b", "theta"))
    pacer = max(float(jnp.abs(sj.pacer.lam - sf.pacer.lam)),
                float(jnp.abs(sj.pacer.c_ema - sf.pacer.c_ema)))
    return arms_ok, stats, pacer


def fused_retrace_check(d=26, B=64, seed=0):
    """New hyper values on a live fused-backend router must re-enter the
    same compiled step (router.TRACE_COUNT stays flat)."""
    state, rng = _warmed_state(d=d, seed=seed)
    cfg = RouterConfig(d=d, max_arms=3, backend="pallas_fused",
                       hyper=HyperParams(alpha=0.05))
    cycle = jax.jit(lambda s, X, R, C: router.step_batch(cfg, s, X, R, C))
    X, R, C = _rand_block(rng, B, d)
    jax.block_until_ready(cycle(state, X, R, C)[0].A)       # compile
    before = router.TRACE_COUNT[0]
    retuned = types_lib.with_hyperparams(state, alpha=0.123, gamma=0.99,
                                         eta=0.1)
    jax.block_until_ready(cycle(retuned, X, R, C)[0].A)
    return router.TRACE_COUNT[0] - before


def fused_main(smoke: bool = False, repeats: int | None = None):
    """Emit ``fused_step.json``: equivalence + retrace gates, per-B
    looped-vs-fused wall clock, and the ``block_r`` autotune table."""
    from repro.kernels import tune
    rows = []

    arms_ok, stats, pacer = fused_step_equivalence(B=256)
    assert arms_ok, "fused megakernel picked different arms than the oracle"
    assert stats <= 1e-4 and pacer <= 1e-4, (stats, pacer)
    rows.append(["fused_equiv_arms_B256", "identical",
                 "megakernel vs jnp oracle, warmed state"])
    rows.append(["fused_equiv_stats_maxdiff", f"{stats:.2e}",
                 "A/A_inv/b/theta after one B=256 block; contract <=1e-4"])
    rows.append(["fused_equiv_pacer_maxdiff", f"{pacer:.2e}",
                 "lam/c_ema after the in-kernel dual fold; contract <=1e-4"])

    retraces = fused_retrace_check()
    assert retraces == 0, f"fused step retraced on new hypers: {retraces}"
    rows.append(["fused_retraces_on_new_hypers", "0",
                 "alpha/gamma/eta retune re-enters the compiled megakernel"])

    # Wall clock: the looped path (pallas score kernel + XLA update scan)
    # vs the fused megakernel, full closed-loop step_batch cycle. Smoke
    # trims the default reps, but an explicit --repeats wins either way:
    # single-core CI hosts need a deeper best-of to shake scheduler noise.
    reps = repeats if repeats is not None else (5 if smoke else 30)
    sweep_t = time_batched_sweep(
        backends=("jnp", "pallas", "pallas_fused"), reps=reps)
    for B in BATCH_SIZES:
        us_j = sweep_t[("jnp", B)][0]
        us_l = sweep_t[("pallas", B)][0]
        us_f = sweep_t[("pallas_fused", B)][0]
        rows.append([f"step_B{B}_us_per_decision",
                     f"jnp={us_j:.2f};looped={us_l:.2f};fused={us_f:.2f}",
                     f"fused_vs_looped={us_l / us_f:.2f}x;"
                     f"fused_vs_jnp={us_j / us_f:.2f}x"])

    best, table = tune.autotune_block_r(
        512 if smoke else 4096, 26, 3, repeats=2 if smoke else 3)
    rows.append(["block_r_autotune_best", str(best),
                 ";".join(f"br{k}={v * 1e3:.2f}ms"
                          for k, v in sorted(table.items()))])
    emit(rows, ["name", "value", "derived"], "fused_step")
    return rows


def main(quick: bool = False):
    rows = []
    n_prod = 200 if quick else 1000
    for d in (26, 385):
        tr, tu = time_production(d, n=n_prod)
        p50r, p95r = _percentiles(tr)
        p50u, p95u = _percentiles(tu)
        thr = 1.0 / (np.mean(tr) + np.mean(tu))
        rows.append([f"paretobandit_d{d}", f"{p50r:.1f}",
                     f"route_p95={p95r:.1f};update_p50={p50u:.1f};"
                     f"update_p95={p95u:.1f};req_s={thr:.0f}"])
    for mode, label in (("sm", "bare_sm"), ("cached_inv", "cached_inv"),
                        ("per_route_inv", "per_route_inv")):
        for d in (26,) if quick else (26, 385):
            n = 200 if quick else (500 if d == 385 else N_CYCLES)
            tr, tu = time_numpy(mode, d, n=n)
            p50r, _ = _percentiles(tr)
            p50u, p95u = _percentiles(tu)
            thr = 1.0 / (np.mean(tr) + np.mean(tu))
            rows.append([f"{label}_d{d}", f"{p50r:.1f}",
                         f"update_p50={p50u:.1f};req_s={thr:.0f}"])
    te, tp, trt, tt = time_e2e(n=50 if quick else 300)
    rows.append(["e2e_pipeline_ms", f"{np.percentile(tt, 50) * 1e3:.2f}",
                 f"embed_p50_ms={np.percentile(te, 50) * 1e3:.2f};"
                 f"pca_p50_ms={np.percentile(tp, 50) * 1e3:.2f};"
                 f"route_p50_us={np.percentile(trt, 50) * 1e6:.1f}"])
    rows.append(["pallas_batch_scoring_req_s",
                 f"{time_pallas_batch(512 if quick else 4096):.0f}",
                 "interpret-mode CPU; TPU is the target"])

    sweep = time_batched_sweep(reps=5 if quick else 30)
    for (bk, B), (us, dps) in sweep.items():
        rows.append([f"batched_{bk}_B{B}", f"{us:.2f}",
                     f"decisions_per_s={dps:.0f};cycle=select_batch+update_batch"])
    for bk in BACKENDS:
        speedup = sweep[(bk, 1)][0] / sweep[(bk, 256)][0]
        rows.append([f"batched_{bk}_B256_vs_B1_speedup", f"{speedup:.1f}",
                     "per-decision latency ratio (acceptance: >=10x)"])
    rows.append(["backend_score_maxdiff", f"{backend_score_divergence():.2e}",
                 "jnp oracle vs pallas kernel; contract <=1e-4"])
    emit(rows, ["name", "p50_us", "derived"], "latency")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced reps (tier-1 CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="fused-step gates + reduced-rep wall clock only, "
                         "emits fused_step.json (CI fused-step job)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="warm-timing repeats for the fused-step section "
                         "(default: 5 under --smoke/--quick, else 30)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU placeholder devices (before jax init)")
    args = ap.parse_args()
    if args.smoke:
        fused_main(smoke=True, repeats=args.repeats)
    else:
        main(quick=args.quick)
        fused_main(smoke=args.quick, repeats=args.repeats)
