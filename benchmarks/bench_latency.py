"""Tables 10-11 / §3.5: routing latency microbenchmark.

Eight configurations isolating three factors, as in the paper:
  * production overhead — the full jitted ParetoBandit route+update cycle;
  * Sherman-Morrison vs full inversion (numpy, same route() code path);
  * PCA dimensionality d=26 vs d=385 (raw-dimension baseline).
Plus the Pallas batched-scoring kernel's oracle path and the end-to-end
pipeline (hash-encode + PCA + route).

Absolute numbers are container-CPU specific; the paper's *relative*
claims (SM update advantage, d^2 scaling, sub-% share of inference
latency) are the reproduction targets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import router
from repro.core.types import HyperParams, RouterConfig, init_state

N_CYCLES = 2000
WARMUP = 200


def _percentiles(ts):
    return (float(np.percentile(ts, 50) * 1e6),
            float(np.percentile(ts, 95) * 1e6))


# ---------------------------------------------------------------------------
# numpy router variants (algorithmic isolation, same route() math)
# ---------------------------------------------------------------------------

class NumpyRouter:
    """LinUCB with static cost penalty; update strategy selectable."""

    def __init__(self, K, d, mode, alpha=0.05, lambda_c=0.3, seed=0):
        rng = np.random.default_rng(seed)
        self.K, self.d, self.mode = K, d, mode
        self.A = np.stack([np.eye(d) for _ in range(K)])
        self.A_inv = np.stack([np.eye(d) for _ in range(K)])
        self.b = np.zeros((K, d))
        self.theta = np.zeros((K, d))
        self.alpha, self.lambda_c = alpha, lambda_c
        self.c_tilde = np.linspace(0, 0.7, K)

    def route(self, x):
        if self.mode == "per_route_inv":
            self.A_inv = np.linalg.inv(self.A)
        s = self.theta @ x
        for k in range(self.K):
            s[k] += self.alpha * np.sqrt(
                max(x @ (self.A_inv[k] @ x), 0.0))
        s -= self.lambda_c * self.c_tilde
        return int(np.argmax(s))

    def update(self, k, x, r):
        self.A[k] += np.outer(x, x)
        self.b[k] += r * x
        if self.mode == "sm":
            Ax = self.A_inv[k] @ x
            self.A_inv[k] -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        elif self.mode == "cached_inv":
            self.A_inv[k] = np.linalg.inv(self.A[k])
        self.theta[k] = self.A_inv[k] @ self.b[k]


def time_numpy(mode, d, n=N_CYCLES):
    r = NumpyRouter(3, d, mode)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((n + WARMUP, d))
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    t_route, t_upd = [], []
    for i, x in enumerate(xs):
        t0 = time.perf_counter()
        k = r.route(x)
        t1 = time.perf_counter()
        r.update(k, x, 0.8)
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


# ---------------------------------------------------------------------------
# production (jitted JAX) router
# ---------------------------------------------------------------------------

def time_production(d, n=N_CYCLES):
    cfg = RouterConfig(d=d, max_arms=3, hyper=HyperParams(alpha=0.05))
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    upd = jax.jit(lambda s, a, x: router.update(
        cfg, s, a, x, jnp.float32(0.8), jnp.float32(1e-4)))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n + WARMUP, d)), jnp.float32)
    # warmup-compile
    dec, state = sel(state, xs[0])
    state = upd(state, dec.arm, xs[0])
    jax.block_until_ready(state.A)
    t_route, t_upd = [], []
    for i in range(n + WARMUP):
        t0 = time.perf_counter()
        dec, state = sel(state, xs[i])
        dec.arm.block_until_ready()
        t1 = time.perf_counter()
        state = upd(state, dec.arm, xs[i])
        state.theta.block_until_ready()
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


def time_e2e(n=300):
    """hash-encode + PCA + route (the paper's Table 11)."""
    from repro.core.features import fit_pca_whitener, hash_encode
    from repro.data import make_request_stream
    rng = np.random.default_rng(0)
    corpus = [r["prompt"] for r in make_request_stream(400, seed=1)]
    raw = np.stack([hash_encode(p) for p in corpus])
    wh = fit_pca_whitener(raw)
    cfg = RouterConfig(max_arms=3, hyper=HyperParams(alpha=0.05))
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    x = wh(jnp.asarray(hash_encode(corpus[0])))
    dec, state = sel(state, x)
    jax.block_until_ready(dec.arm)
    t_embed, t_pca, t_route, t_total = [], [], [], []
    for i in range(n):
        p = corpus[i % len(corpus)]
        t0 = time.perf_counter()
        raw_v = hash_encode(p)
        t1 = time.perf_counter()
        x = wh(jnp.asarray(raw_v))
        x.block_until_ready()
        t2 = time.perf_counter()
        dec, state = sel(state, x)
        dec.arm.block_until_ready()
        t3 = time.perf_counter()
        t_embed.append(t1 - t0)
        t_pca.append(t2 - t1)
        t_route.append(t3 - t2)
        t_total.append(t3 - t0)
    return t_embed, t_pca, t_route, t_total


def time_pallas_batch(n_requests=4096):
    """Batched UCB scoring kernel throughput (requests/s)."""
    from repro.kernels.linucb_score.ops import linucb_score
    rng = np.random.default_rng(0)
    d, K = 26, 3
    x = jnp.asarray(rng.standard_normal((n_requests, d)), jnp.float32)
    theta = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
    M = rng.standard_normal((K, d, d)) * 0.1
    A = np.einsum("kij,klj->kil", M, M) + np.eye(d)[None]
    ainv = jnp.asarray(np.linalg.inv(A), jnp.float32)
    pen = jnp.asarray([0.0, 0.1, 0.2], jnp.float32)
    infl = jnp.ones((K,), jnp.float32)
    out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return n_requests / dt


# ---------------------------------------------------------------------------
# batched data plane: full select_batch + update_batch cycle per backend
# ---------------------------------------------------------------------------

BATCH_SIZES = (1, 8, 64, 256)
BACKENDS = ("jnp", "pallas")


def time_batched_sweep(batch_sizes=BATCH_SIZES, backends=BACKENDS,
                       reps=30, d=26, seed=0):
    """Batched routing throughput: decisions/s and µs/decision for the
    full route+update block cycle, per backend and block size.

    Returns {(backend, B): (us_per_decision, decisions_per_s)}.
    """
    rng = np.random.default_rng(seed)
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3], jnp.float32)
    out = {}
    for bk in backends:
        cfg = RouterConfig(d=d, max_arms=3, backend=bk,
                           hyper=HyperParams(alpha=0.05))

        def cycle(s, X, R, C, cfg=cfg):
            return router.step_batch(cfg, s, X, R, C)

        cycle = jax.jit(cycle)
        for B in batch_sizes:
            state = init_state(cfg, prices, prices, budget=6.6e-4)
            X = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
            R = jnp.asarray(rng.uniform(0.5, 1.0, (B, 3)), jnp.float32)
            C = jnp.asarray(rng.uniform(1e-5, 1e-3, (B, 3)), jnp.float32)
            state, _ = cycle(state, X, R, C)   # compile
            jax.block_until_ready(state.A)
            t0 = time.perf_counter()
            for _ in range(reps):
                state, trace = cycle(state, X, R, C)
            jax.block_until_ready(state.A)
            dt = (time.perf_counter() - t0) / reps
            out[(bk, B)] = (dt / B * 1e6, B / dt)
    return out


def backend_score_divergence(B=256, d=26, K=3, seed=0):
    """Max abs score diff jnp vs Pallas on one block (the ≤1e-4 contract)."""
    from repro.core import backend as backend_lib
    rng = np.random.default_rng(seed)
    cfg = RouterConfig(d=d, max_arms=K, hyper=HyperParams(alpha=0.05))
    theta = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
    M = rng.standard_normal((K, d, d)) * 0.1
    A = np.einsum("kij,klj->kil", M, M) + np.eye(d)[None]
    ainv = jnp.asarray(np.linalg.inv(A), jnp.float32)
    c_tilde = jnp.asarray(np.linspace(0.0, 0.7, K), jnp.float32)
    X = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    dt = jnp.asarray(rng.integers(0, 500, K), jnp.int32)
    return backend_lib.score_divergence(
        cfg, cfg.hyper.as_leaves(), theta, ainv, c_tilde, X, dt,
        jnp.float32(0.7))


def main(quick: bool = False):
    rows = []
    n_prod = 200 if quick else 1000
    for d in (26, 385):
        tr, tu = time_production(d, n=n_prod)
        p50r, p95r = _percentiles(tr)
        p50u, p95u = _percentiles(tu)
        thr = 1.0 / (np.mean(tr) + np.mean(tu))
        rows.append([f"paretobandit_d{d}", f"{p50r:.1f}",
                     f"route_p95={p95r:.1f};update_p50={p50u:.1f};"
                     f"update_p95={p95u:.1f};req_s={thr:.0f}"])
    for mode, label in (("sm", "bare_sm"), ("cached_inv", "cached_inv"),
                        ("per_route_inv", "per_route_inv")):
        for d in (26,) if quick else (26, 385):
            n = 200 if quick else (500 if d == 385 else N_CYCLES)
            tr, tu = time_numpy(mode, d, n=n)
            p50r, _ = _percentiles(tr)
            p50u, p95u = _percentiles(tu)
            thr = 1.0 / (np.mean(tr) + np.mean(tu))
            rows.append([f"{label}_d{d}", f"{p50r:.1f}",
                         f"update_p50={p50u:.1f};req_s={thr:.0f}"])
    te, tp, trt, tt = time_e2e(n=50 if quick else 300)
    rows.append(["e2e_pipeline_ms", f"{np.percentile(tt, 50) * 1e3:.2f}",
                 f"embed_p50_ms={np.percentile(te, 50) * 1e3:.2f};"
                 f"pca_p50_ms={np.percentile(tp, 50) * 1e3:.2f};"
                 f"route_p50_us={np.percentile(trt, 50) * 1e6:.1f}"])
    rows.append(["pallas_batch_scoring_req_s",
                 f"{time_pallas_batch(512 if quick else 4096):.0f}",
                 "interpret-mode CPU; TPU is the target"])

    sweep = time_batched_sweep(reps=5 if quick else 30)
    for (bk, B), (us, dps) in sweep.items():
        rows.append([f"batched_{bk}_B{B}", f"{us:.2f}",
                     f"decisions_per_s={dps:.0f};cycle=select_batch+update_batch"])
    for bk in BACKENDS:
        speedup = sweep[(bk, 1)][0] / sweep[(bk, 256)][0]
        rows.append([f"batched_{bk}_B256_vs_B1_speedup", f"{speedup:.1f}",
                     "per-decision latency ratio (acceptance: >=10x)"])
    rows.append(["backend_score_maxdiff", f"{backend_score_divergence():.2e}",
                 "jnp oracle vs pallas kernel; contract <=1e-4"])
    emit(rows, ["name", "p50_us", "derived"], "latency")
    return rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
