"""Tables 10-11 / §3.5: routing latency microbenchmark.

Eight configurations isolating three factors, as in the paper:
  * production overhead — the full jitted ParetoBandit route+update cycle;
  * Sherman-Morrison vs full inversion (numpy, same route() code path);
  * PCA dimensionality d=26 vs d=385 (raw-dimension baseline).
Plus the Pallas batched-scoring kernel's oracle path and the end-to-end
pipeline (hash-encode + PCA + route).

Absolute numbers are container-CPU specific; the paper's *relative*
claims (SM update advantage, d^2 scaling, sub-% share of inference
latency) are the reproduction targets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import router
from repro.core.types import RouterConfig, init_state

N_CYCLES = 2000
WARMUP = 200


def _percentiles(ts):
    return (float(np.percentile(ts, 50) * 1e6),
            float(np.percentile(ts, 95) * 1e6))


# ---------------------------------------------------------------------------
# numpy router variants (algorithmic isolation, same route() math)
# ---------------------------------------------------------------------------

class NumpyRouter:
    """LinUCB with static cost penalty; update strategy selectable."""

    def __init__(self, K, d, mode, alpha=0.05, lambda_c=0.3, seed=0):
        rng = np.random.default_rng(seed)
        self.K, self.d, self.mode = K, d, mode
        self.A = np.stack([np.eye(d) for _ in range(K)])
        self.A_inv = np.stack([np.eye(d) for _ in range(K)])
        self.b = np.zeros((K, d))
        self.theta = np.zeros((K, d))
        self.alpha, self.lambda_c = alpha, lambda_c
        self.c_tilde = np.linspace(0, 0.7, K)

    def route(self, x):
        if self.mode == "per_route_inv":
            self.A_inv = np.linalg.inv(self.A)
        s = self.theta @ x
        for k in range(self.K):
            s[k] += self.alpha * np.sqrt(
                max(x @ (self.A_inv[k] @ x), 0.0))
        s -= self.lambda_c * self.c_tilde
        return int(np.argmax(s))

    def update(self, k, x, r):
        self.A[k] += np.outer(x, x)
        self.b[k] += r * x
        if self.mode == "sm":
            Ax = self.A_inv[k] @ x
            self.A_inv[k] -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        elif self.mode == "cached_inv":
            self.A_inv[k] = np.linalg.inv(self.A[k])
        self.theta[k] = self.A_inv[k] @ self.b[k]


def time_numpy(mode, d, n=N_CYCLES):
    r = NumpyRouter(3, d, mode)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((n + WARMUP, d))
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    t_route, t_upd = [], []
    for i, x in enumerate(xs):
        t0 = time.perf_counter()
        k = r.route(x)
        t1 = time.perf_counter()
        r.update(k, x, 0.8)
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


# ---------------------------------------------------------------------------
# production (jitted JAX) router
# ---------------------------------------------------------------------------

def time_production(d, n=N_CYCLES):
    cfg = RouterConfig(d=d, max_arms=3, alpha=0.05)
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    upd = jax.jit(lambda s, a, x: router.update(
        cfg, s, a, x, jnp.float32(0.8), jnp.float32(1e-4)))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n + WARMUP, d)), jnp.float32)
    # warmup-compile
    dec, state = sel(state, xs[0])
    state = upd(state, dec.arm, xs[0])
    jax.block_until_ready(state.A)
    t_route, t_upd = [], []
    for i in range(n + WARMUP):
        t0 = time.perf_counter()
        dec, state = sel(state, xs[i])
        dec.arm.block_until_ready()
        t1 = time.perf_counter()
        state = upd(state, dec.arm, xs[i])
        state.theta.block_until_ready()
        t2 = time.perf_counter()
        if i >= WARMUP:
            t_route.append(t1 - t0)
            t_upd.append(t2 - t1)
    return t_route, t_upd


def time_e2e(n=300):
    """hash-encode + PCA + route (the paper's Table 11)."""
    from repro.core.features import fit_pca_whitener, hash_encode
    from repro.data import make_request_stream
    rng = np.random.default_rng(0)
    corpus = [r["prompt"] for r in make_request_stream(400, seed=1)]
    raw = np.stack([hash_encode(p) for p in corpus])
    wh = fit_pca_whitener(raw)
    cfg = RouterConfig(max_arms=3, alpha=0.05)
    prices = jnp.asarray([1e-4, 1e-3, 5.6e-3])
    state = init_state(cfg, prices, prices, budget=6.6e-4)
    sel = jax.jit(lambda s, x: router.select(cfg, s, x))
    x = wh(jnp.asarray(hash_encode(corpus[0])))
    dec, state = sel(state, x)
    jax.block_until_ready(dec.arm)
    t_embed, t_pca, t_route, t_total = [], [], [], []
    for i in range(n):
        p = corpus[i % len(corpus)]
        t0 = time.perf_counter()
        raw_v = hash_encode(p)
        t1 = time.perf_counter()
        x = wh(jnp.asarray(raw_v))
        x.block_until_ready()
        t2 = time.perf_counter()
        dec, state = sel(state, x)
        dec.arm.block_until_ready()
        t3 = time.perf_counter()
        t_embed.append(t1 - t0)
        t_pca.append(t2 - t1)
        t_route.append(t3 - t2)
        t_total.append(t3 - t0)
    return t_embed, t_pca, t_route, t_total


def time_pallas_batch(n_requests=4096):
    """Batched UCB scoring kernel throughput (requests/s)."""
    from repro.kernels.linucb_score.ops import linucb_score
    rng = np.random.default_rng(0)
    d, K = 26, 3
    x = jnp.asarray(rng.standard_normal((n_requests, d)), jnp.float32)
    theta = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
    M = rng.standard_normal((K, d, d)) * 0.1
    A = np.einsum("kij,klj->kil", M, M) + np.eye(d)[None]
    ainv = jnp.asarray(np.linalg.inv(A), jnp.float32)
    pen = jnp.asarray([0.0, 0.1, 0.2], jnp.float32)
    infl = jnp.ones((K,), jnp.float32)
    out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        out = linucb_score(x, theta, ainv, pen, infl, alpha=0.05)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return n_requests / dt


def main():
    rows = []
    for d in (26, 385):
        tr, tu = time_production(d, n=1000)
        p50r, p95r = _percentiles(tr)
        p50u, p95u = _percentiles(tu)
        thr = 1.0 / (np.mean(tr) + np.mean(tu))
        rows.append([f"paretobandit_d{d}", f"{p50r:.1f}",
                     f"route_p95={p95r:.1f};update_p50={p50u:.1f};"
                     f"update_p95={p95u:.1f};req_s={thr:.0f}"])
    for mode, label in (("sm", "bare_sm"), ("cached_inv", "cached_inv"),
                        ("per_route_inv", "per_route_inv")):
        for d in (26, 385):
            n = 500 if d == 385 else N_CYCLES
            tr, tu = time_numpy(mode, d, n=n)
            p50r, _ = _percentiles(tr)
            p50u, p95u = _percentiles(tu)
            thr = 1.0 / (np.mean(tr) + np.mean(tu))
            rows.append([f"{label}_d{d}", f"{p50r:.1f}",
                         f"update_p50={p50u:.1f};req_s={thr:.0f}"])
    te, tp, trt, tt = time_e2e()
    rows.append(["e2e_pipeline_ms", f"{np.percentile(tt, 50) * 1e3:.2f}",
                 f"embed_p50_ms={np.percentile(te, 50) * 1e3:.2f};"
                 f"pca_p50_ms={np.percentile(tp, 50) * 1e3:.2f};"
                 f"route_p50_us={np.percentile(trt, 50) * 1e6:.1f}"])
    rows.append(["pallas_batch_scoring_req_s", f"{time_pallas_batch():.0f}",
                 "interpret-mode CPU; TPU is the target"])
    emit(rows, ["name", "p50_us", "derived"], "latency")
    return rows


if __name__ == "__main__":
    main()
