"""Scenario-engine generality: three new multi-event scenarios, each run
through BOTH the scalar and the batched (B=64) data plane.

Scenarios beyond the paper's protocols, authored as ``ScenarioSpec`` data
(no bespoke phase loops):

  * price_war        — two providers reprice simultaneously (Gemini to
                       $0.10/M, Mistral to 0.2x) and restore together;
  * add_then_regress — a good-cheap newcomer is hot-swapped in, adopted,
                       then silently regresses to 0.60 mean reward;
  * budget_tighten   — the operator cuts the ceiling from loose to tight
                       mid-stream (a pure control-plane event: same
                       prompts, same arms, new pacer target);
  * mix_shift        — traffic tilts to math/code families (Gemini's
                       niche) and back, stressing contextual routing.

``--smoke`` runs a tiny spec exercising EVERY event type on a reduced
environment (CI's scenario-engine smoke job). ``--budget-grid`` runs
scenario x budget matrices through the sweep fabric: each spec's whole
(budget x seed) grid is ONE compiled, device-sharded call
(``sweep.run_scenario_grid``). ``--param-grid`` runs whole spec
*families* — price cuts at several magnitudes, regressions to several
quality targets — as fused (payload x budget x seed) grids via
``Param`` payloads riding the condition axis (DESIGN.md §10), gated
bit-identical against looping ``run_scenario`` over the equivalent
concrete-payload specs and timed looped-vs-fused (CI's
scenario-param-grid job with ``--smoke --devices N``).
"""
from __future__ import annotations

import sys

from benchmarks._devices import apply_devices_flag

apply_devices_flag(sys.argv)  # must precede any jax import

import argparse
import time

import numpy as np

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, SEEDS, benchmark, emit, warmup_priors,
)
from repro.core import evaluate, scenario, simulator, sweep
from tests.trace_guard import assert_traces
from repro.core.costs import BUDGET_LOOSE, BUDGET_TIGHT
from repro.core.scenario import (
    AddArm, BudgetChange, DeleteArm, Param, PriceChange, QualityShift,
    ScenarioParams, ScenarioSpec, TrafficMixShift,
)

PHASE = 608
LLAMA, MISTRAL, GEMINI, FLASH = 0, 1, 2, 3
BATCH = 64

PRICE_WAR = ScenarioSpec(
    horizon=3 * PHASE,
    events=(
        PriceChange(PHASE, GEMINI, (0.10 / 1e3) / 5.6e-3),
        PriceChange(PHASE, MISTRAL, 0.2),
        PriceChange(2 * PHASE, GEMINI, 1.0),
        PriceChange(2 * PHASE, MISTRAL, 1.0),
    ),
    stream_seed_base=5000,
    replay=((2, 0),),
)

ADD_THEN_REGRESS = ScenarioSpec(
    horizon=3 * PHASE,
    events=(
        AddArm(PHASE, FLASH, n_eff=None, forced_exploration=True),
        QualityShift(2 * PHASE, FLASH, 0.60),
    ),
    stream_seed_base=5100,
    init_active=3,
)

BUDGET_TIGHTEN = ScenarioSpec(
    horizon=3 * PHASE,
    events=(BudgetChange(PHASE + PHASE // 2, BUDGET_TIGHT),),
    stream_seed_base=5200,
)

# Families: mmlu, gsm8k, hellaswag, bbh, arc, obqa, winogrande, tqa, mbpp.
_MATH_CODE_MIX = (0.5, 3.0, 0.5, 2.0, 0.5, 0.5, 0.5, 0.5, 3.0)

MIX_SHIFT = ScenarioSpec(
    horizon=3 * PHASE,
    events=(
        TrafficMixShift(PHASE, _MATH_CODE_MIX),
        TrafficMixShift(2 * PHASE, None),
    ),
    stream_seed_base=5300,
)


def _run_both_planes(spec, env, budget, seeds, priors):
    """Scalar + batched runs of one spec; identical trace shapes."""
    kw = dict(seeds=seeds, priors=priors, n_eff=N_EFF)
    scalar = evaluate.run_scenario(PARETO_CFG, spec, env, budget, **kw)
    batched = evaluate.run_scenario(PARETO_CFG, spec, env, budget,
                                    batch_size=BATCH, **kw)
    assert scalar.arms.shape == batched.arms.shape, (
        scalar.arms.shape, batched.arms.shape)
    assert scalar.bounds == batched.bounds
    return scalar, batched


def _seg_summary(res, budget, arm):
    segs = []
    for j in range(res.n_segments):
        s = res.segment(j)
        segs.append(f"P{j+1}:r={s.mean_reward:.3f}"
                    f"|x={s.compliance(budget):.2f}"
                    f"|arm{arm}={s.allocation(arm + 1)[arm]:.2f}")
    return ";".join(segs)


def main(seeds=SEEDS):
    b = benchmark()
    rows = []
    pri3 = list(warmup_priors())

    cases = [
        ("price_war", PRICE_WAR, b.test, BUDGET_LOOSE, pri3, GEMINI),
        ("add_then_regress", ADD_THEN_REGRESS,
         simulator.extend_with_flash(b.test, "good_cheap"), 6.6e-4,
         pri3 + [None], FLASH),
        ("budget_tighten", BUDGET_TIGHTEN, b.test, BUDGET_LOOSE, pri3,
         GEMINI),
        ("mix_shift", MIX_SHIFT, b.test, 6.6e-4, pri3, GEMINI),
    ]
    scalar_results = {}
    for name, spec, env, budget, priors, arm in cases:
        scalar, batched = _run_both_planes(spec, env, budget, seeds, priors)
        scalar_results[name] = scalar
        rows.append([f"scenario_{name}_scalar", f"{budget:.2e}",
                     _seg_summary(scalar, budget, arm)])
        rows.append([f"scenario_{name}_b{BATCH}", f"{budget:.2e}",
                     _seg_summary(batched, budget, arm)])

    # budget_tighten: compliance vs the ceiling in force per side.
    res = scalar_results["budget_tighten"]
    cut = BUDGET_TIGHTEN.events[0].t
    before = res.phase(0, cut).compliance(BUDGET_LOOSE)
    # judge the tightened regime on its converged tail
    after = res.phase((cut + 3 * PHASE) // 2, 3 * PHASE).compliance(
        BUDGET_TIGHT)
    rows.append(["scenario_budget_tighten_compliance",
                 f"{before:.2f}->{after:.2f}",
                 f"ceiling {BUDGET_LOOSE:.1e}->{BUDGET_TIGHT:.1e} at "
                 f"t={cut}"])
    emit(rows, ["name", "value", "derived"], "scenarios")
    return rows


# Scenario x budget matrices (§4 tables): initial ceilings for the grid
# mode; each scenario's whole matrix is ONE sharded fabric call.
GRID_BUDGETS = (1.0e-4, BUDGET_TIGHT, 6.6e-4, BUDGET_LOOSE, 4.0e-3)


def budget_grid(seeds=SEEDS, budgets=GRID_BUDGETS):
    """Scenario x budget matrices: for each scenario spec, run the whole
    (budget x seed) grid through ``sweep.run_scenario_grid`` — the
    segmented scan is vmapped over the flattened grid and sharded across
    devices, so a five-ceiling matrix costs one compile and one dispatch
    instead of five."""
    b = benchmark()
    pri3 = list(warmup_priors())
    rows = []
    cases = [
        ("price_war", PRICE_WAR, b.test, pri3, GEMINI),
        ("add_then_regress", ADD_THEN_REGRESS,
         simulator.extend_with_flash(b.test, "good_cheap"), pri3 + [None],
         FLASH),
        ("mix_shift", MIX_SHIFT, b.test, pri3, GEMINI),
    ]
    for name, spec, env, priors, arm in cases:
        grid = sweep.run_scenario_grid(
            PARETO_CFG, spec, env, budgets, seeds=seeds,
            priors=priors, n_eff=N_EFF)
        for budget, res in grid.conditions():
            segs = _seg_summary(res, budget, arm)
            rows.append([f"scenario_grid_{name}", f"{budget:.2e}", segs])
    emit(rows, ["name", "budget", "derived"], "scenario_budget_grid")
    return rows


# Payload families (--param-grid): the §4.3 cost-drift protocol at
# several repricing magnitudes and the §4.4 degradation protocol at
# several quality targets — each family ONE fused fabric call.
PRICE_MULTS = (1 / 56, 0.05, 0.2, 0.5, 2.0)
QUALITY_TARGETS = (0.45, 0.60, 0.75, 0.90)
GEMINI_RESTORE = 1.0


def _drift_family_spec(mult, phase, base):
    return ScenarioSpec(
        horizon=3 * phase,
        events=(PriceChange(phase, GEMINI, mult),
                PriceChange(2 * phase, GEMINI, GEMINI_RESTORE)),
        stream_seed_base=base, replay=((2, 0),))


def _regress_family_spec(target, phase, base):
    return ScenarioSpec(
        horizon=3 * phase,
        events=(QualityShift(phase, MISTRAL, target),
                QualityShift(2 * phase, MISTRAL, None)),
        stream_seed_base=base, replay=((2, 0),))


def _time(fn, repeats):
    t0 = time.perf_counter()
    fn()
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        warm = min(warm, time.perf_counter() - t0)
    return cold, warm


def _clear_scenario_caches():
    scenario._RUNNER_CACHE.clear()
    scenario._STREAM_CACHE.clear()
    sweep._SCEN_CACHE.clear()


def _one_family(name, env, spec_of, param_spec, pname, payloads, budgets,
                seeds, priors, repeats, rows):
    """Gate + time one payload family: fused (payload x budget x seed)
    grid vs looping run_scenario over concrete-payload specs."""
    b_flat = tuple(np.tile(budgets, len(payloads)))
    p_flat = np.repeat(np.asarray(payloads, np.float32), len(budgets))
    kw = dict(seeds=seeds, priors=priors, n_eff=N_EFF)

    def looped():
        return [evaluate.run_scenario(PARETO_CFG, spec_of(float(p)), env,
                                      float(b), **kw)
                for p, b in zip(p_flat, b_flat)]

    def fused():
        return sweep.run_scenario_grid(
            PARETO_CFG, param_spec, env, b_flat,
            scenario_params=ScenarioParams(**{pname: p_flat}), **kw)

    # Bit-identity gate before any timing: every fused condition must
    # equal its looped concrete-payload twin, and the whole family must
    # compile exactly once.
    base = looped()
    with assert_traces(sweep, 1, what=f"{name}: payload family must "
                                      "compile as ONE program"):
        grid = fused()
    for i, res in enumerate(base):
        np.testing.assert_array_equal(grid.condition(i).arms, res.arms)
        np.testing.assert_array_equal(grid.condition(i).rewards,
                                      res.rewards)
        np.testing.assert_array_equal(grid.condition(i).costs, res.costs)
        np.testing.assert_array_equal(grid.condition(i).lams, res.lams)
    rows.append([f"param_grid_{name}_equivalence", "bit_identical",
                 f"{len(payloads)}x{len(budgets)}x{len(seeds)} grid"])

    # Cold timings need fresh programs on both sides.
    _clear_scenario_caches()
    looped_cold, looped_warm = _time(looped, repeats)
    _clear_scenario_caches()
    fused_cold, fused_warm = _time(fused, repeats)
    import jax
    rows.append([f"param_grid_{name}_looped_s", f"{looped_warm:.3f}",
                 f"cold={looped_cold:.3f}"])
    rows.append([f"param_grid_{name}_fused_s", f"{fused_warm:.3f}",
                 f"cold={fused_cold:.3f};devices={len(jax.devices())}"])
    rows.append([f"param_grid_{name}_speedup",
                 f"{looped_warm / fused_warm:.2f}x",
                 f"cold {looped_cold / fused_cold:.2f}x"])
    return grid


def param_grid(smoke: bool = False, repeats: int = 2):
    """Fused payload grids: (price-multiplier x budget x seed) and
    (quality-target x budget x seed), each ONE compiled, device-sharded
    call, bit-identical to the looped concrete-spec protocol."""
    if smoke:
        b = simulator.make_benchmark(
            seed=0, splits={"train": 256, "val": 32, "test": 200})
        env, phase, seeds = b.test, 40, (0, 1)
        mults, targets = PRICE_MULTS[:2], QUALITY_TARGETS[:2]
        budgets = (BUDGETS["tight"], BUDGETS["moderate"])
        priors, repeats = None, 1   # cold-start family: no warm priors
    else:
        env, phase, seeds = benchmark().test, PHASE, SEEDS
        mults, targets = PRICE_MULTS, QUALITY_TARGETS
        budgets = tuple(BUDGETS.values())
        priors = list(warmup_priors())

    rows = []
    pri = priors
    _one_family(
        "price", env,
        lambda m: _drift_family_spec(m, phase, 7000),
        _drift_family_spec(Param("mult"), phase, 7000), "mult",
        mults, budgets, seeds, pri, repeats, rows)
    _one_family(
        "quality", env,
        lambda t: _regress_family_spec(t, phase, 7100),
        _regress_family_spec(Param("target"), phase, 7100), "target",
        targets, budgets, seeds, pri, repeats, rows)
    emit(rows, ["name", "value", "derived"], "scenario_param_grid")
    return rows


# Scenario Monte Carlo (--mc-grid): randomized event *times* and
# horizons over one spec, all timelines ONE fused call (DESIGN.md §12).
# The spec mixes three event types — a silent price shock, a silent
# quality regression and an operator budget cut — whose arrival steps
# (and the effective horizon) are drawn uniformly per timeline.
MC_SEED = 11
MC_PROBE = 16   # looped-baseline sample: bit-identity gate + timing


def _mc_spec(T):
    return ScenarioSpec(
        horizon=T,
        events=(
            PriceChange(T // 3, GEMINI, 1 / 56),
            QualityShift(T // 2, MISTRAL, 0.70),
            BudgetChange(2 * T // 3, BUDGET_TIGHT),
        ),
        stream_seed_base=7200)


def mc_grid(smoke: bool = False, n_timelines: int = 1024, repeats: int = 2):
    """Scenario Monte Carlo over randomized timelines: N sampled
    (event-times, horizon) draws of one spec run as ONE compiled call,
    gated bit-identical against looping ``run_scenario`` over the
    concrete retimed specs, then timed looped-vs-fused.

    The looped baseline pays one compile PER timeline (times are trace
    constants on that path), so at N=1024 it is hours of XLA time; it is
    measured on a ``MC_PROBE``-timeline sample and extrapolated linearly
    to N (fair: the runner LRU holds 64 programs, so at N=1024 every
    looped timeline recompiles). Both the measured probe numbers and the
    at-scale extrapolation are recorded."""
    from repro.core import montecarlo

    if smoke:
        b = simulator.make_benchmark(
            seed=0, splits={"train": 256, "val": 32, "test": 200})
        env, T, N = b.test, 120, 12
        probe, repeats = N, 1
    else:
        env, T, N = benchmark().test, 240, n_timelines
        probe = MC_PROBE
    spec, budget, seeds = _mc_spec(T), BUDGETS["moderate"], (0,)
    tls = montecarlo.sample_timelines(
        spec, N, seed=MC_SEED, horizons=(3 * T // 4, T))
    kw = dict(seeds=seeds, n_eff=N_EFF)

    def fused(timelines=tls):
        return sweep.run_scenario_grid(
            PARETO_CFG, spec, env, [budget] * len(timelines),
            timelines=timelines, **kw)

    rows = []
    # --- gates before any timing ---------------------------------------
    # (1) ONE compile for the whole Monte Carlo,
    with assert_traces(sweep, 1, what="Monte Carlo grid must compile "
                                      "as ONE program"):
        grid = fused()
    # (2) resampled timelines (same grid shape) re-enter with zero
    # retraces,
    resampled = montecarlo.sample_timelines(
        spec, N, seed=MC_SEED + 1, horizons=(3 * T // 4, T))
    with assert_traces(sweep, 0, what="new event times must be data, "
                                      "not structure"):
        fused(resampled)
    rows.append(["mc_grid_compile_once", "1",
                 f"N={N};resample_retraces=0"])
    # (3) every probed timeline bit-identical to its looped baseline.
    idx = np.linspace(0, N - 1, probe).astype(int)
    for i in idx:
        ref = evaluate.run_scenario(
            PARETO_CFG, scenario.retime(spec, tls[i]), env, budget, **kw)
        res = grid.condition(int(i))
        np.testing.assert_array_equal(ref.arms, res.arms)
        np.testing.assert_array_equal(ref.rewards, res.rewards)
        np.testing.assert_array_equal(ref.costs, res.costs)
        np.testing.assert_array_equal(ref.lams, res.lams)
    rows.append(["mc_grid_bit_identity", "bit_identical",
                 f"{probe}/{N} timelines gated vs looped run_scenario"])

    # --- looped-vs-fused wall clock ------------------------------------
    probe_tls = [tls[i] for i in idx]

    def looped():
        return [evaluate.run_scenario(
                    PARETO_CFG, scenario.retime(spec, tl), env, budget,
                    **kw)
                for tl in probe_tls]

    _clear_scenario_caches()
    looped_cold, looped_warm = _time(looped, repeats)
    _clear_scenario_caches()
    fused_cold, fused_warm = _time(fused, repeats)
    scale = N / probe
    looped_cold_scaled = looped_cold * scale
    speedup_cold = looped_cold_scaled / fused_cold
    import jax
    rows.append(["mc_grid_looped_probe_s", f"{looped_warm:.3f}",
                 f"cold={looped_cold:.3f};probe={probe}"])
    rows.append(["mc_grid_fused_s", f"{fused_warm:.3f}",
                 f"cold={fused_cold:.3f};N={N};"
                 f"devices={len(jax.devices())}"])
    rows.append(["mc_grid_cold_speedup", f"{speedup_cold:.1f}x",
                 f"looped cold extrapolated x{scale:.0f} to N={N}: "
                 f"{looped_cold_scaled:.1f}s vs fused {fused_cold:.3f}s"])
    rows.append(["mc_grid_warm_speedup",
                 f"{looped_warm * scale / fused_warm:.1f}x",
                 f"looped warm extrapolated x{scale:.0f}"])
    if not smoke:
        assert speedup_cold >= 5.0, (
            f"fused must win cold by >=5x at N={N}: got {speedup_cold:.1f}x")

    # --- percentile bands (the numbers replacing the paper's single-
    # timeline point estimates) -----------------------------------------
    mc = montecarlo.MonteCarloResult(
        grid=grid, timelines=tls, budget=budget,
        **_mc_metrics(grid, tls, spec, budget))
    bands = mc.bands((5, 25, 50, 75, 95))
    lag = bands["adaptation_lag"]
    rows.append(["mc_grid_adaptation_lag_p50",
                 ";".join(f"{v:.0f}" for v in lag["p50"]),
                 f"p5={lag['p5']};p95={lag['p95']};per event"])
    rows.append(["mc_grid_quality_lift_p50",
                 f"{bands['quality_lift']['p50']:.4f}",
                 f"p5={bands['quality_lift']['p5']:.4f};"
                 f"p95={bands['quality_lift']['p95']:.4f}"])
    rows.append(["mc_grid_compliance_p50",
                 f"{bands['budget_compliance']['p50']:.3f}",
                 f"p5={bands['budget_compliance']['p5']:.3f};"
                 f"p95={bands['budget_compliance']['p95']:.3f}"])
    emit(rows, ["name", "value", "derived"], "scenario_mc", derived=bands)
    return rows


def _mc_metrics(grid, tls, spec, budget):
    """Per-timeline metric arrays from an already-run MC grid (avoids a
    second fused call just to reuse ``run_monte_carlo``)."""
    from repro.core import montecarlo
    E = len(spec.events)
    lags = np.empty((len(tls), E))
    lifts = np.empty(len(tls))
    comp = np.empty(len(tls))
    for i, tl in enumerate(tls):
        res = grid.condition(i)
        for j, t in enumerate(tl.event_ts):
            lags[i, j] = montecarlo.adaptation_lag(res, t)
        segs = [res.segment(j) for j in range(res.n_segments)]
        nonempty = [s for s in segs if s.arms.shape[1] > 0]
        lifts[i] = nonempty[-1].mean_reward - nonempty[0].mean_reward
        comp[i] = res.mean_cost / budget
    return dict(lags=lags, lifts=lifts, compliance=comp)


def smoke():
    """CI smoke: every event type in one tiny spec, both data planes."""
    bench = simulator.make_benchmark(
        seed=0, splits={"train": 256, "val": 32, "test": 200})
    env4 = simulator.extend_with_flash(bench.test, "good_cheap")
    spec = ScenarioSpec(
        horizon=120,
        events=(
            PriceChange(20, GEMINI, 0.1, recalibrate=True),
            QualityShift(40, MISTRAL, 0.7),
            AddArm(60, FLASH),
            BudgetChange(80, BUDGET_TIGHT),
            TrafficMixShift(90, _MATH_CODE_MIX),
            DeleteArm(100, FLASH),
        ),
        init_active=3,
    )
    rows = []
    for bs in (None, 16):
        res = evaluate.run_scenario(
            PARETO_CFG, spec, env4, BUDGET_LOOSE, seeds=(0, 1),
            batch_size=bs)
        assert res.arms.shape == (2, 120)
        assert res.n_segments == 7   # 6 event times + the opening segment
        assert np.isfinite(res.mean_cost)
        # deleted newcomer never routed after retirement
        assert not np.any(res.segment(6).arms == FLASH)
        rows.append([f"scenario_smoke_b{bs or 1}",
                     f"{res.mean_reward:.3f}",
                     f"segments={res.n_segments};cost={res.mean_cost:.2e}"])
    emit(rows, ["name", "reward", "derived"], "scenario_smoke")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny every-event-type spec (CI); with "
                         "--param-grid, shrinks the payload grids")
    ap.add_argument("--budget-grid", action="store_true",
                    help="scenario x budget matrices via the sweep fabric")
    ap.add_argument("--param-grid", action="store_true",
                    help="fused (payload x budget x seed) spec families "
                         "with bit-identity gate + looped-vs-fused timing")
    ap.add_argument("--mc-grid", action="store_true",
                    help="scenario Monte Carlo over randomized timelines "
                         "(one fused call, bit-identity gate, percentile "
                         "bands); with --smoke, a 12-timeline CI job")
    ap.add_argument("--timelines", type=int, default=1024,
                    help="Monte Carlo sample size for --mc-grid")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU placeholder devices (before jax init)")
    args = ap.parse_args()
    if args.mc_grid:
        mc_grid(smoke=args.smoke, n_timelines=args.timelines)
    elif args.param_grid:
        param_grid(smoke=args.smoke)
    elif args.smoke:
        smoke()
    elif args.budget_grid:
        budget_grid()
    else:
        main()
