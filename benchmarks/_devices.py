"""Pre-jax-import device-count bootstrap shared by the sharded-fabric
benchmarks (bench_sweep, bench_knee).

``--devices N`` forces N CPU placeholder devices via
``xla_force_host_platform_device_count`` (dryrun.py's convention) so the
device-sharded path is exercised on machines without accelerators. The
flag must be applied BEFORE jax initialises, hence this module is
jax-free and callers invoke ``apply_devices_flag(sys.argv)`` at the very
top, ahead of any jax-touching import.
"""
from __future__ import annotations

import os


def peek_devices(argv) -> int:
    """--devices N or --devices=N, parsed without argparse/jax."""
    for i, a in enumerate(argv):
        if a == "--devices":
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 0


def apply_devices_flag(argv) -> int:
    n = peek_devices(argv)
    if n:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=" + str(n))
    return n
