"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only NAME ...]``

Prints ``name,value,derived`` CSV rows per benchmark (and saves JSON
under benchmarks/results/).
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = [
    ("pareto", "Fig. 1: stationary budget pacing frontier"),
    ("cost_drift", "Table 2 / Fig. 2: budget pacing under cost drift"),
    ("degradation", "Fig. 3: silent quality degradation"),
    ("onboarding", "Figs. 4-5: cold-start model onboarding"),
    ("knee", "Tables 3-4: Pareto knee-point hyperparameters"),
    ("warmup", "Table 5: warmup-prior ablation"),
    ("prior_mismatch", "Fig. 9: prior mismatch sensitivity"),
    ("judges", "App. E: reward-signal robustness across judges"),
    ("cost_heuristic", "App. B: cost heuristic validation"),
    ("recovery_limit", "App. G: recovery limit"),
    ("scenarios", "Scenario engine: new multi-event scenarios, both planes"),
    ("scenario_grid", "Scenario x budget matrices via the sweep fabric"),
    ("scenario_param_grid",
     "Fused (payload x budget x seed) spec families, looped-vs-fused"),
    ("scenario_mc",
     "Scenario Monte Carlo: randomized timelines as one fused call"),
    ("sweep", "Sweep fabric: looped-vs-fabric grid wall clock"),
    ("gateway",
     "Serving gateway: decoupled-plane decisions/sec + select p95"),
    ("tenants",
     "Multi-tenant pacing: per-tenant fold identity + 0.4% compliance"),
    ("latency", "Tables 10-11: routing latency microbenchmark"),
    ("roofline", "Roofline: dry-run roofline table"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer seeds (CI smoke)")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)

    import importlib
    # Entries whose module or entrypoint differs from bench_{name}.main().
    MODULES = {"scenario_grid": "scenarios",
               "scenario_param_grid": "scenarios",
               "scenario_mc": "scenarios"}
    failures = []
    for name, desc in BENCHES:
        if args.only and name not in args.only:
            continue
        mod = importlib.import_module(
            f"benchmarks.bench_{MODULES.get(name, name)}")
        print(f"# === {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            if name == "sweep":
                mod.main(argv=["--smoke"] if args.quick else [])
            elif name == "scenario_grid":
                mod.budget_grid(seeds=tuple(range(5)) if args.quick
                                else tuple(range(20)))
            elif name == "scenario_param_grid":
                mod.param_grid(smoke=args.quick)
            elif name == "scenario_mc":
                mod.mc_grid(smoke=args.quick)
            elif name in ("gateway", "tenants"):
                mod.main(smoke=args.quick)
            elif args.quick and name in ("pareto", "cost_drift",
                                         "degradation", "onboarding",
                                         "warmup", "prior_mismatch",
                                         "judges", "scenarios"):
                mod.main(seeds=tuple(range(5)))
            elif args.quick and name in ("knee", "recovery_limit"):
                mod.main(seeds=tuple(range(3)))
            else:
                mod.main()
        except Exception:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
