"""Figure 9 / Appendix D: prior-mismatch sensitivity.

Five prior-quality levels (well-calibrated, random-1680, MMLU-only,
GSM8K-only, inverted) x n_eff in {10, 100, 1000}, unconstrained regime,
vs the independently optimised Tabula Rasa baseline.

The stationary protocol is the event-free ``ScenarioSpec``: one segment
covering the test split as a seed-specific permutation (the engine's
"permutation" mode reproduces ``evaluate.run``'s shuffle convention).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    SEEDS, TABULA_CFG, PARETO_CFG, benchmark, emit,
)
from repro.core import evaluate
from repro.core.scenario import ScenarioSpec

LLAMA, MISTRAL, GEMINI = 0, 1, 2


def stationary_spec(horizon: int) -> ScenarioSpec:
    return ScenarioSpec(horizon=horizon, events=(),
                        stream_seed_base=0, mode="permutation")


def _priors_from(env_subset):
    return evaluate.fit_warmup_priors(PARETO_CFG, env_subset)


def prior_variants(b):
    train = b.train
    rng = np.random.default_rng(0)
    fam = train.families
    variants = {
        "well_calibrated": _priors_from(train),
        "random_1680": _priors_from(
            train.subset(rng.choice(train.n, 1680, replace=False))),
        "mmlu_only": _priors_from(train.subset(np.where(fam == 0)[0])),
        "gsm8k_only": _priors_from(train.subset(np.where(fam == 1)[0])),
    }
    # Inverted: swap Llama and Gemini reward columns before fitting.
    import dataclasses
    rewards = train.rewards.copy()
    rewards[:, [LLAMA, GEMINI]] = rewards[:, [GEMINI, LLAMA]]
    inv = dataclasses.replace(train, rewards=rewards)
    variants["inverted"] = _priors_from(inv)
    return variants


def regrets(res, env, seeds):
    oracle = env.rewards.max(axis=1)
    out = []
    for i, s in enumerate(seeds):
        perm = np.random.default_rng(int(s)).permutation(env.n)
        out.append((oracle[perm] - res.rewards[i]).sum())
    return np.asarray(out)


def main(seeds=SEEDS):
    b = benchmark()
    env = b.test
    spec = stationary_spec(env.n)
    rows = []
    res_t = evaluate.run_scenario(TABULA_CFG, spec, env, 1.0, seeds=seeds)
    reg_t = regrets(res_t, env, seeds)
    med_t = float(np.median(reg_t))
    rows.append(["tabula_rasa", f"{med_t:.1f}",
                 f"std={reg_t.std():.1f}"])
    for name, priors in prior_variants(b).items():
        for n_eff in (10.0, 100.0, 1000.0):
            res = evaluate.run_scenario(PARETO_CFG, spec, env, 1.0,
                                        seeds=seeds, priors=priors,
                                        n_eff=n_eff)
            reg = regrets(res, env, seeds)
            med = float(np.median(reg))
            cat = int((reg > 2 * med_t).sum())
            rows.append([
                f"prior_{name}_neff{int(n_eff)}", f"{med:.1f}",
                f"std={reg.std():.1f};cat={cat}/{len(seeds)};"
                f"vs_tr={100 * (med_t - med) / med_t:+.1f}%"])
    emit(rows, ["name", "median_regret", "derived"], "prior_mismatch")
    return rows


if __name__ == "__main__":
    main()
