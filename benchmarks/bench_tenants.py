"""Multi-tenant budget pacing benchmark (DESIGN.md §15).

Gates, then times, the tenant plane under a flash-crowd traffic mix at
T in {4, 64} tenants sharing one portfolio's LinUCB statistics:

  * per-tenant fold identity — in the fused run, every tenant's final
    pacer row (lam, c_ema, pulls, spend) must be BIT-identical to
    folding that tenant's cost subsequence through the single-tenant
    ``pacer_update_batch`` in arrival order (the §15 segment-sum
    contract: tenant rows are disjoint, interleaving preserves
    within-tenant order);
  * per-tenant budget compliance — steady-state mean realized cost
    within the paper's 0.4% line of EVERY tenant's ceiling (budgets are
    calibrated binding; forced exploration is off so the dual is the
    only controller);
  * fused-vs-looped — a (tenant-table x seed) grid through ONE
    ``sweep.run_grid`` call must be bit-identical per condition to the
    looped ``evaluate.run`` it replaces, and the wall-clock of both is
    recorded;
  * zero-retrace — re-running T=64 with NEW tenant budgets must not
    retrace (budgets are pacer-leaf DATA, not trace constants).

``--smoke`` runs the reduced grid (the CI multitenant-smoke job) and
emits the same ``benchmarks/results/tenants.json`` artifact.

The compliance testbed uses a 10x price spread (1e-4 / 3e-4 / 1e-3 per
request) instead of the calibrated benchmark's 500x: with lambda_bar=5
the hard ceiling cannot price out the mid arm of a 500x spread, so
sub-mid-arm ceilings are structurally infeasible there — a property of
the environment, not the pacer.
"""
from __future__ import annotations

import functools
import sys
import time

from benchmarks._devices import apply_devices_flag

apply_devices_flag(sys.argv)  # must precede any jax import

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import N_EFF, emit
from tests.trace_guard import assert_traces
from repro.core import evaluate, pacer, router, simulator, sweep, tenancy
from repro.core.types import HyperParams, PacerState, RouterConfig
from repro.data import synthetic

CFG = RouterConfig(hyper=HyperParams(alpha=0.01, gamma=0.997),
                   forced_pulls=0)
PRICES_PER_REQ = np.array([1e-4, 3e-4, 1e-3])
BUDGETS_T4 = np.array([1.8e-4, 2.1e-4, 2.4e-4, 2.8e-4], np.float32)
COMPLIANCE_LINE = 0.004          # the paper's 0.4% budget-compliance line


@functools.lru_cache(maxsize=4)
def testbed(n: int):
    """Benchmark env with the 10x price spread + its warmup priors."""
    p1k = PRICES_PER_REQ * 1e3 / simulator.MEAN_REQ_TOKENS
    b = simulator.make_benchmark(
        seed=0, prices_per_1k=p1k,
        splits={"train": 8374, "val": 1785, "test": n})
    priors = tuple(evaluate.fit_warmup_priors(CFG, b.train))
    return b.test, list(priors)[: b.test.k]


def tenant_budgets(T: int) -> np.ndarray:
    """T binding ceilings: the calibrated T=4 set, or log-uniform draws
    from the same binding band for larger fleets."""
    if T == 4:
        return BUDGETS_T4
    rng = np.random.default_rng(0)
    return np.exp(rng.uniform(np.log(1.8e-4), np.log(2.8e-4), T)).astype(
        np.float32)


def flash_mix(n: int, T: int) -> np.ndarray:
    """The §4 flash-crowd stressor on the tenant axis: one tenant's
    share spikes 8x through the middle half-window."""
    return synthetic.flash_crowd_tenant_stream(
        n, T, hot=min(3, T - 1), start=n // 4, stop=n // 2, boost=8.0,
        seed=7)


def run_fleet(n: int, T: int, seeds, budgets=None, tids=None):
    env, priors = testbed(n)
    budgets = tenant_budgets(T) if budgets is None else budgets
    tids = flash_mix(n, T) if tids is None else tids
    res, finals = evaluate.run(
        CFG, env, 1.0, seeds, batch_size=64, priors=priors, n_eff=N_EFF,
        tenants=tenancy.make_table(budgets), tenant_ids=tids,
        return_states=True)
    return res, finals, budgets, tids


def gate_fold_identity(n=4096, T=8, seeds=(0, 1)):
    """Fused tenant plane == looped single-tenant pacer folds, bit for
    bit: tenant j's final row must equal folding its own cost
    subsequence through ``pacer_update_batch`` from the fresh row."""
    res, finals, budgets, tids = run_fleet(n, T, seeds)
    tab = finals.tenants
    hp = CFG.hyper
    for s in range(len(seeds)):
        for j in range(T):
            cs = np.asarray(res.costs[s][tids == j], np.float32)
            p0 = PacerState(
                lam=jnp.float32(0.0), c_ema=jnp.float32(budgets[j]),
                budget=jnp.float32(budgets[j]), enabled=jnp.asarray(True))
            pf = pacer.pacer_update_batch(hp, p0, jnp.asarray(cs))
            got_lam = np.asarray(tab.lam)[s, j]
            got_ema = np.asarray(tab.c_ema)[s, j]
            assert got_lam == np.asarray(pf.lam), (
                f"seed {s} tenant {j}: lam diverged "
                f"({got_lam} != {np.asarray(pf.lam)})")
            assert got_ema == np.asarray(pf.c_ema), (
                f"seed {s} tenant {j}: c_ema diverged")
            assert int(np.asarray(tab.pulls)[s, j]) == len(cs)
            spend = np.float32(0.0)
            for c in cs:                 # same arrival-order f32 adds
                spend = np.float32(spend + c)
            assert np.asarray(tab.spend)[s, j] == spend, (
                f"seed {s} tenant {j}: spend diverged")
    return len(seeds) * T


def compliance(n: int, T: int, seeds):
    """Per-tenant |steady-state mean cost / ceiling - 1| over the
    post-burn-in half of the stream, all seeds pooled."""
    res, _finals, budgets, tids = run_fleet(n, T, seeds)
    costs = np.asarray(res.costs, np.float64)
    window = np.arange(n) >= n // 2
    devs = []
    for j in range(T):
        m = (tids == j) & window
        devs.append(abs(float(costs[:, m].mean() / budgets[j]) - 1.0))
    return devs


def fused_vs_looped(n: int, seeds, scales=(1.0, 1.25, 1.5)):
    """A (tenant-table x seed) fleet grid as ONE run_grid call vs the
    Python loop of per-condition evaluate.run: bit-identity gate +
    both wall clocks."""
    env, priors = testbed(n)
    T = 4
    tids = flash_mix(n, T)
    tables = [tenancy.make_table(BUDGETS_T4 * np.float32(f))
              for f in scales]
    stacked = tenancy.stack_tables(tables)
    kw = dict(priors=priors, n_eff=N_EFF, batch_size=64)
    C = len(scales)

    def fused():
        return sweep.run_grid(
            CFG, env, [1.0] * C, seeds, tenant_tables=stacked,
            tenant_ids=tids, **kw)

    def looped():
        return [evaluate.run(CFG, env, 1.0, seeds, tenants=t,
                             tenant_ids=tids, **kw) for t in tables]

    grid, runs = fused(), looped()          # warm both compiled paths
    for i in range(C):
        cond = grid.condition(i)
        assert np.array_equal(cond.arms, runs[i].arms), (
            f"condition {i}: fused grid arms != looped run arms")
        assert np.array_equal(cond.costs, runs[i].costs), (
            f"condition {i}: fused grid costs != looped run costs")

    t0 = time.perf_counter()
    jax.block_until_ready(fused().lams)
    fused_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for r in looped():
        jax.block_until_ready(r.lams)
    looped_s = time.perf_counter() - t0
    return fused_s, looped_s, C


def gate_zero_retrace(n=4096, T=64, seeds=(0, 1)):
    """New tenant budgets are DATA: the second fleet run (fresh budget
    values, same shapes) must re-enter the same compiled program."""
    run_fleet(n, T, seeds)                       # trace + compile once
    rng = np.random.default_rng(12)
    fresh = np.exp(rng.uniform(np.log(1.8e-4), np.log(2.8e-4), T)).astype(
        np.float32)
    with assert_traces(router, 0,
                       what="tenant fleet retraced on new budgets") as tg:
        run_fleet(n, T, seeds, budgets=fresh)
    return tg.before


def main(smoke: bool = False):
    rows = []

    checked = gate_fold_identity()
    rows.append(["fold_identity", "1",
                 f"{checked} (seed,tenant) rows: fused lam/c_ema/pulls/"
                 "spend == looped single-tenant pacer folds, bitwise"])

    traces = gate_zero_retrace()
    rows.append(["zero_retraces_T64", "1",
                 f"TRACE_COUNT frozen at {traces} across fresh budgets"])

    t4 = dict(n=32768, seeds=tuple(range(8 if smoke else 16)))
    devs4 = compliance(T=4, **t4)
    assert max(devs4) <= COMPLIANCE_LINE, (
        f"T=4 compliance breached: per-tenant devs {devs4}")
    rows.append(["compliance_max_dev_T4", f"{max(devs4):.5f}",
                 f"n={t4['n']};seeds={len(t4['seeds'])};"
                 f"gate<={COMPLIANCE_LINE}; all 4 tenants"])

    if smoke:
        # smoke keeps the T=64 fleet small: the compliance estimator
        # needs ~4M tenant-steps to resolve 0.4%, so the hard gate on
        # every tenant runs in full mode only
        devs64 = compliance(n=32768, T=64, seeds=tuple(range(4)))
        rows.append(["compliance_max_dev_T64", f"{max(devs64):.5f}",
                     "n=32768;seeds=4;report-only in smoke "
                     f"(mean_dev={float(np.mean(devs64)):.5f})"])
    else:
        devs64 = compliance(n=262144, T=64, seeds=tuple(range(32)))
        assert max(devs64) <= COMPLIANCE_LINE, (
            f"T=64 compliance breached: max dev {max(devs64)}")
        rows.append(["compliance_max_dev_T64", f"{max(devs64):.5f}",
                     f"n=262144;seeds=32;gate<={COMPLIANCE_LINE}; "
                     "all 64 tenants"])

    n_fl = 8192 if smoke else 32768
    seeds_fl = tuple(range(4 if smoke else 8))
    fused_s, looped_s, C = fused_vs_looped(n_fl, seeds_fl)
    rows.append(["fleet_fused_s", f"{fused_s:.3f}",
                 f"C={C} tenant tables x {len(seeds_fl)} seeds, one "
                 "run_grid call; bit-identical to looped per condition"])
    rows.append(["fleet_looped_s", f"{looped_s:.3f}",
                 f"speedup={looped_s / fused_s:.2f}x"])

    emit(rows, ["name", "value", "derived"], "tenants")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced fleet (CI multitenant-smoke job)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N CPU placeholder devices (before jax init)")
    args = ap.parse_args()
    main(smoke=args.smoke)
