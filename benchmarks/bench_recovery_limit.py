"""Appendix G: recovery limit under quality degradation.

Sweeps Mistral's degraded reward from 0.05 to 0.85 (moderate budget),
measuring the Phase-3/Phase-1 reward ratio at the base (608) and extended
(1216) horizons.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, SEEDS, benchmark, emit, warmup_priors,
)
from repro.core import evaluate, simulator

MISTRAL = 1
PHASE = 608
SEVERITIES = (0.05, 0.25, 0.45, 0.65, 0.75, 0.85)


def run(target, horizon, seeds):
    b = benchmark()
    env = b.test
    priors = list(warmup_priors())
    envs = []
    for s in seeds:
        rng = np.random.default_rng(6000 + s)
        idx1 = rng.integers(0, env.n, PHASE)
        idx2 = rng.integers(0, env.n, PHASE)
        idx3 = rng.integers(0, env.n, horizon)
        p1 = env.subset(idx1)
        p2 = simulator.with_quality_shift(env, MISTRAL, target).subset(idx2)
        p3 = env.subset(idx3)  # fresh prompts, i.i.d. preserved
        envs.append(simulator.concat_environments((p1, p2, p3)))
    res = evaluate.run(PARETO_CFG, envs, BUDGETS["moderate"], seeds=seeds,
                       priors=priors, n_eff=N_EFF, shuffle=False)
    r1 = res.phase(0, PHASE).mean_reward
    # recovery measured on the TAIL of phase 3 (converged region)
    r3 = res.phase(PHASE + PHASE + horizon // 2, 2 * PHASE + horizon).mean_reward
    return r3 / r1


def main(seeds=tuple(range(10))):
    rows = []
    for sev in SEVERITIES:
        base = run(sev, PHASE, seeds)
        ext = run(sev, 2 * PHASE, seeds)
        rows.append([f"recovery_target{sev:.2f}", f"{base:.3f}",
                     f"extended={ext:.3f}"])
    emit(rows, ["name", "p3_over_p1", "derived"], "recovery_limit")
    return rows


if __name__ == "__main__":
    main()
