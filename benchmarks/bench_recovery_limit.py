"""Appendix G: recovery limit under quality degradation.

Sweeps Mistral's degraded reward from 0.05 to 0.85 (moderate budget),
measuring the Phase-3/Phase-1 reward ratio at the base (608) and extended
(1216) horizons. Each (severity, horizon) cell is a two-event
``ScenarioSpec`` (degrade, restore) with fresh i.i.d. phase-3 prompts.
"""
from __future__ import annotations

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, benchmark, emit, warmup_priors,
)
from repro.core import evaluate
from repro.core.scenario import QualityShift, ScenarioSpec

MISTRAL = 1
PHASE = 608
SEVERITIES = (0.05, 0.25, 0.45, 0.65, 0.75, 0.85)


def recovery_spec(target: float, horizon: int) -> ScenarioSpec:
    return ScenarioSpec(
        horizon=2 * PHASE + horizon,
        events=(
            QualityShift(PHASE, MISTRAL, target),
            QualityShift(2 * PHASE, MISTRAL, None),
        ),
        stream_seed_base=6000,    # phase 3 draws fresh prompts (no replay)
    )


def run(target, horizon, seeds):
    res = evaluate.run_scenario(
        PARETO_CFG, recovery_spec(target, horizon), benchmark().test,
        BUDGETS["moderate"], seeds=seeds,
        priors=list(warmup_priors()), n_eff=N_EFF)
    r1 = res.segment(0).mean_reward
    # recovery measured on the TAIL of phase 3 (converged region)
    r3 = res.phase(PHASE + PHASE + horizon // 2, 2 * PHASE + horizon).mean_reward
    return r3 / r1


def main(seeds=tuple(range(10))):
    rows = []
    for sev in SEVERITIES:
        base = run(sev, PHASE, seeds)
        ext = run(sev, 2 * PHASE, seeds)
        rows.append([f"recovery_target{sev:.2f}", f"{base:.3f}",
                     f"extended={ext:.3f}"])
    emit(rows, ["name", "p3_over_p1", "derived"], "recovery_limit")
    return rows


if __name__ == "__main__":
    main()
