"""Appendix G: recovery limit under quality degradation.

Sweeps Mistral's degraded reward from 0.05 to 0.85 (moderate budget),
measuring the Phase-3/Phase-1 reward ratio at the base (608) and extended
(1216) horizons. The severity axis is a ``Param`` payload (DESIGN.md
§10): per horizon, the whole six-severity family runs as ONE fused
fabric call (``sweep.run_scenario_grid`` with a stacked ``target``
leaf) — two compiles total instead of one per (severity, horizon) cell,
bit-identical per condition to the looped concrete-spec protocol.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, benchmark, emit, warmup_priors,
)
from repro.core import sweep
from repro.core.scenario import (
    Param, QualityShift, ScenarioParams, ScenarioSpec,
)

MISTRAL = 1
PHASE = 608
SEVERITIES = (0.05, 0.25, 0.45, 0.65, 0.75, 0.85)


def recovery_spec(target, horizon: int) -> ScenarioSpec:
    """``target`` may be a ``Param`` (the fused sweep passes
    ``Param("target")`` and stacks severities on the condition axis)."""
    return ScenarioSpec(
        horizon=2 * PHASE + horizon,
        events=(
            QualityShift(PHASE, MISTRAL, target),
            QualityShift(2 * PHASE, MISTRAL, None),
        ),
        stream_seed_base=6000,    # phase 3 draws fresh prompts (no replay)
    )


def run_severity_family(horizon, seeds, severities=SEVERITIES):
    """All severities at one horizon as ONE fused grid; returns the
    per-severity Phase-3-tail / Phase-1 reward ratios."""
    grid = sweep.run_scenario_grid(
        PARETO_CFG, recovery_spec(Param("target"), horizon),
        benchmark().test, (BUDGETS["moderate"],) * len(severities),
        seeds=seeds, priors=list(warmup_priors()), n_eff=N_EFF,
        scenario_params=ScenarioParams(
            target=np.asarray(severities, np.float32)))
    ratios = []
    for i in range(len(severities)):
        res = grid.condition(i)
        r1 = res.segment(0).mean_reward
        # recovery measured on the TAIL of phase 3 (converged region)
        r3 = res.phase(PHASE + PHASE + horizon // 2,
                       2 * PHASE + horizon).mean_reward
        ratios.append(r3 / r1)
    return ratios


def main(seeds=tuple(range(10))):
    base = run_severity_family(PHASE, seeds)
    ext = run_severity_family(2 * PHASE, seeds)
    rows = []
    for sev, b, e in zip(SEVERITIES, base, ext):
        rows.append([f"recovery_target{sev:.2f}", f"{b:.3f}",
                     f"extended={e:.3f}"])
    emit(rows, ["name", "p3_over_p1", "derived"], "recovery_limit")
    return rows


if __name__ == "__main__":
    main()
