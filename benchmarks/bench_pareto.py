"""Figure 1: stationary budget pacing — the quality-cost Pareto frontier.

Sweeps seven budget ceilings (plus unconstrained) as ONE compiled,
device-sharded grid call (the sweep fabric — the budget is a
``PacerState`` leaf, so the whole grid shares one trace), reporting
realised cost, compliance, quality and per-arm allocation; prints the
fixed-model anchor points and the oracle for comparison.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BUDGETS, SEEDS, benchmark, bootstrap_ci, emit, run_condition_grid,
)
from repro.core import simulator

# Seven ceilings spanning the operating range (log-spaced) — the paper's
# three named regimes are among them.
BUDGET_SWEEP = [1.0e-4, 2.3e-4, 3.0e-4, 6.6e-4, 1.0e-3, 1.9e-3, 4.0e-3]


def main(seeds=SEEDS):
    b = benchmark()
    env = b.test
    rows = []
    header = ["name", "value", "derived"]

    for cost, q in simulator.fixed_model_points(env):
        rows.append([f"fixed_model_cost", f"{cost:.3e}", f"quality={q:.4f}"])
    oracle = simulator.oracle_reward(env)
    rows.append(["oracle_reward", f"{oracle:.4f}", ""])

    # Seven ceilings + unconstrained: one fabric call, one compile.
    grid = run_condition_grid(
        "pareto", env, list(BUDGET_SWEEP) + [1.0], seeds=seeds)
    for i, budget in enumerate(BUDGET_SWEEP):
        res = grid.condition(i)
        per_seed = res.costs.mean(axis=1) / budget
        m, lo, hi = bootstrap_ci(per_seed)
        alloc = [round(float(a), 3) for a in res.allocation(env.k)]
        rows.append([
            "pareto_frontier", f"{budget:.2e}",
            f"reward={res.mean_reward:.4f};compliance={m:.3f}"
            f"[{lo:.3f},{hi:.3f}];alloc={list(alloc)}",
        ])

    res = grid.condition(len(BUDGET_SWEEP))  # unconstrained (B = $1/req)
    frac = res.mean_reward / oracle
    rows.append(["unconstrained_oracle_frac", f"{frac:.4f}",
                 f"reward={res.mean_reward:.4f}"])
    emit(rows, header, "pareto")
    return rows


if __name__ == "__main__":
    main()
