"""Figures 4-5 / §4.5: cold-start model onboarding (K=3 -> K=4).

After a Phase-1 learning period on the 3-model portfolio,
Gemini-2.5-Flash is hot-swapped in with no priors and a 20-pull forced
exploration. Three scenarios x four budgets; reports adoption share,
steps-to-adoption, rejection of the bad arm, and compliance through the
transition.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, SEEDS, benchmark, emit, warmup_priors,
)
from repro.core import evaluate, registry, simulator

PHASE1 = 608
PHASE2 = 1216
FLASH = 3


def run_scenario(scenario: str, budget: float, seeds):
    b = benchmark()
    env4 = simulator.extend_with_flash(b.test, scenario)
    priors = list(warmup_priors()) + [None]
    rng = np.random.default_rng(7)
    stream1 = [env4.repeat_to(PHASE1, np.random.default_rng(3000 + s))
               for s in seeds]
    stream2 = [env4.repeat_to(PHASE2, np.random.default_rng(4000 + s))
               for s in seeds]

    # Phase 1: only the 3 original arms active.
    states = evaluate.make_states(
        PARETO_CFG, env4, budget, seeds, priors=priors, n_eff=N_EFF,
        active_arms=3)
    res1, states = evaluate.run(
        PARETO_CFG, stream1, budget, seeds=seeds, states=states,
        shuffle=False, return_states=True)

    # Hot swap: register Flash (uninformative, forced exploration).
    add = functools.partial(
        registry.add_arm, PARETO_CFG,
        slot=FLASH,
        price_per_req=float(env4.prices_per_req[FLASH]),
        price_per_1k=float(env4.prices_per_1k[FLASH]),
        n_eff=None, forced_exploration=True)
    states = jax.vmap(lambda st: add(st))(states)

    res2, _ = evaluate.run(
        PARETO_CFG, stream2, budget, seeds=seeds, states=states,
        shuffle=False, return_states=True)
    return res1, res2


def adoption_step(res2, window=50, threshold=0.02, burn_in=20):
    """First step after the forced-exploration burn-in where the windowed
    Flash share rises above threshold and stays there on average."""
    sel = (res2.arms == FLASH).astype(float)      # (S, T)
    share = sel.mean(axis=0)
    kernel = np.ones(window) / window
    smooth = np.convolve(share, kernel, mode="same")
    for t in range(burn_in + window, len(smooth)):
        if smooth[t] > threshold and smooth[t:].mean() > threshold:
            return t
    return -1


def main(seeds=SEEDS):
    rows = []
    budgets = dict(BUDGETS)
    budgets["unconstrained"] = 1.0
    for scenario in ("good_cheap", "good_expensive", "bad_cheap"):
        for bname, budget in budgets.items():
            res1, res2 = run_scenario(scenario, budget, seeds)
            share_tail = float((res2.arms[:, PHASE2 // 2:] == FLASH).mean())
            step = adoption_step(res2)
            # compliance measured post-transition (the 20 forced pulls of
            # an expensive newcomer are a bounded, visible spike — Fig. 5)
            comp2 = res2.phase(100, PHASE2).compliance(budget)
            comp_spike = res2.phase(0, 100).compliance(budget)
            rows.append([
                f"onboarding_{scenario}_{bname}", f"{share_tail:.4f}",
                f"adoption_step={step};compliance_post={comp2:.2f};"
                f"burnin_spike={comp_spike:.2f}",
            ])
    emit(rows, ["name", "flash_share", "derived"], "onboarding")
    return rows


if __name__ == "__main__":
    main()
