"""Figures 4-5 / §4.5: cold-start model onboarding (K=3 -> K=4).

After a Phase-1 learning period on the 3-model portfolio,
Gemini-2.5-Flash is hot-swapped in with no priors and a 20-pull forced
exploration. Three scenarios x four budgets; reports adoption share,
steps-to-adoption, rejection of the bad arm, and compliance through the
transition.

The hot swap is a ``ScenarioSpec``: one timed ``AddArm`` event on a
4-column environment whose 4th slot starts inactive — the full K=3 -> K=4
run is one jitted call, with ``registry.add_arm`` applied between scan
segments inside the compiled program.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BUDGETS, N_EFF, PARETO_CFG, SEEDS, benchmark, emit, warmup_priors,
)
from repro.core import evaluate, simulator
from repro.core.scenario import AddArm, ScenarioSpec

PHASE1 = 608
PHASE2 = 1216
FLASH = 3

ONBOARDING_SPEC = ScenarioSpec(
    horizon=PHASE1 + PHASE2,
    events=(AddArm(PHASE1, FLASH, n_eff=None, forced_exploration=True),),
    segment_seeds=(3000, 4000),   # fresh per-segment draws (legacy layout)
    init_active=3,                # Flash's slot starts inactive
)


def run_scenario(scenario: str, budget: float, seeds):
    env4 = simulator.extend_with_flash(benchmark().test, scenario)
    priors = list(warmup_priors()) + [None]
    res = evaluate.run_scenario(
        PARETO_CFG, ONBOARDING_SPEC, env4, budget, seeds=seeds,
        priors=priors, n_eff=N_EFF)
    return res.segment(0), res.segment(1)


def adoption_step(res2, window=50, threshold=0.02, burn_in=20):
    """First step after the forced-exploration burn-in where the windowed
    Flash share rises above threshold and stays there on average."""
    sel = (res2.arms == FLASH).astype(float)      # (S, T)
    share = sel.mean(axis=0)
    kernel = np.ones(window) / window
    smooth = np.convolve(share, kernel, mode="same")
    for t in range(burn_in + window, len(smooth)):
        if smooth[t] > threshold and smooth[t:].mean() > threshold:
            return t
    return -1


def main(seeds=SEEDS):
    rows = []
    budgets = dict(BUDGETS)
    budgets["unconstrained"] = 1.0
    for scenario in ("good_cheap", "good_expensive", "bad_cheap"):
        for bname, budget in budgets.items():
            res1, res2 = run_scenario(scenario, budget, seeds)
            share_tail = float((res2.arms[:, PHASE2 // 2:] == FLASH).mean())
            step = adoption_step(res2)
            # compliance measured post-transition (the 20 forced pulls of
            # an expensive newcomer are a bounded, visible spike — Fig. 5)
            comp2 = res2.phase(100, PHASE2).compliance(budget)
            comp_spike = res2.phase(0, 100).compliance(budget)
            rows.append([
                f"onboarding_{scenario}_{bname}", f"{share_tail:.4f}",
                f"adoption_step={step};compliance_post={comp2:.2f};"
                f"burnin_spike={comp_spike:.2f}",
            ])
    emit(rows, ["name", "flash_share", "derived"], "onboarding")
    return rows


if __name__ == "__main__":
    main()
