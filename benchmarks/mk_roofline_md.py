"""Emit the EXPERIMENTS.md roofline table from dry-run JSONs."""
import glob
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(r):
    mem_gb = r["memory"]["peak_bytes"] / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | {mem_gb:.1f} |")


def main(d="benchmarks/results/dryrun", mesh="16x16"):
    rows = []
    for p in sorted(glob.glob(os.path.join(d, f"*_{mesh}.json"))):
        r = json.load(open(p))
        if r.get("skipped"):
            rows.append((r["arch"], r["shape"],
                         f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — |"))
            continue
        rows.append((r["arch"], r["shape"], fmt(r)))
    rows.sort(key=lambda t: (t[0], ORDER.index(t[1])))
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | useful | peak GB |")
    print("|---|---|---|---|---|---|---|---|")
    for _, _, line in rows:
        print(line)


if __name__ == "__main__":
    main(*sys.argv[1:])
