"""§Roofline: tabulate the dry-run results (one row per arch x shape x
mesh) with the three roofline terms, the dominant bottleneck, and the
useful-FLOPs ratio. Reads benchmarks/results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit


def main():
    rows = []
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.json")))
    if not paths:
        rows.append(["roofline", "no dryrun results",
                     "run: python -m repro.launch.dryrun --all"])
        emit(rows, ["name", "value", "derived"], "roofline")
        return rows
    for p in paths:
        with open(p) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append([f"{r['arch']}|{r['shape']}|{r.get('mesh','-')}",
                         "skipped", r["reason"]])
            continue
        rows.append([
            f"{r['arch']}|{r['shape']}|{r['mesh']}",
            f"{r['bound_s']:.4f}s",
            f"dom={r['dominant']};compute={r['compute_s']:.4f};"
            f"memory={r['memory_s']:.4f};coll={r['collective_s']:.4f};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"peakGB={r['memory']['peak_bytes'] / 1e9:.1f}",
        ])
    emit(rows, ["name", "bound", "derived"], "roofline")
    return rows


if __name__ == "__main__":
    main()
