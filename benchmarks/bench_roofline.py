"""§Roofline: tabulate the dry-run results (one row per arch x shape x
mesh) with the three roofline terms, the dominant bottleneck, and the
useful-FLOPs ratio. Reads benchmarks/results/dryrun/*.json.

Also emits the analytic arithmetic-intensity model for the fused step
megakernel (DESIGN.md §11): fusing the whole per-block bandit body into
one ``pallas_call`` leaves the FLOP count essentially unchanged but
collapses the HBM traffic — the sufficient statistics are read and
written ONCE per block instead of round-tripping per phase (and, in the
update scan, per request) — so the kernel's FLOPs/byte rises toward the
compute-bound regime as B grows.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit


def step_cost_model(B: int, K: int, d: int, fused: bool):
    """(FLOPs, HBM bytes) for one closed-loop step-block (f32).

    FLOPs count the same math either way — scoring (per-arm quadratic
    form matmuls dominate), B Sherman-Morrison updates, theta refresh
    (K matvecs fused — only the block-final theta is observable — vs B
    per-request ones looped). Bytes model HBM traffic: the fused kernel
    reads + writes the stats exactly once (aliased in/out, VMEM
    resident); the looped path re-reads the inverses for scoring and
    round-trips the chosen arm's (A, A_inv, b, theta) slabs through HBM
    on every request of the update scan.
    """
    flops_score = 2 * B * K * d * d + 2 * B * K * d + 5 * B * K
    flops_update = B * (4 * d * d       # gamma-decay A and A_inv
                        + 2 * d * d     # + outer(x, x)
                        + 2 * d * d     # A_inv @ x matvec
                        + d * d + 2 * d  # - outer(Ax, Ax) / denom
                        + 3 * d)        # b decay + r*x
    flops_theta = (K if fused else B) * 2 * d * d
    flops = flops_score + flops_update + flops_theta
    stats = 4 * (2 * K * d * d + 2 * K * d + K)     # A, A_inv, b, theta, lu
    streams = 4 * (B * d + 3 * B * K + 3 * B)       # X, R/C/noise, outputs
    if fused:
        bytes_ = 2 * stats + streams                # one read + one write
    else:
        score_read = 4 * (K * d * d + K * d)        # A_inv + theta again
        upd_rw = 8 * B * (2 * d * d + 2 * d)        # per-request slab r/w
        bytes_ = 2 * stats + score_read + upd_rw + streams
    return flops, bytes_


def fused_intensity_rows():
    """Arithmetic-intensity table: fused megakernel vs looped path."""
    rows = []
    for B, K, d in ((64, 3, 26), (256, 3, 26), (256, 8, 128)):
        ff, bf = step_cost_model(B, K, d, fused=True)
        fl, bl = step_cost_model(B, K, d, fused=False)
        ai_f, ai_l = ff / bf, fl / bl
        rows.append([
            f"fused_step_intensity_B{B}_K{K}_d{d}",
            f"{ai_f:.2f}",
            f"flop_per_byte_looped={ai_l:.2f};gain={ai_f / ai_l:.2f}x;"
            f"bytes_fused={bf / 1e3:.1f}KB;bytes_looped={bl / 1e3:.1f}KB;"
            f"mflop={ff / 1e6:.2f}",
        ])
    return rows


def main():
    rows = fused_intensity_rows()
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.json")))
    if not paths:
        rows.append(["roofline", "no dryrun results",
                     "run: python -m repro.launch.dryrun --all"])
        emit(rows, ["name", "value", "derived"], "roofline")
        return rows
    for p in paths:
        with open(p) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append([f"{r['arch']}|{r['shape']}|{r.get('mesh','-')}",
                         "skipped", r["reason"]])
            continue
        rows.append([
            f"{r['arch']}|{r['shape']}|{r['mesh']}",
            f"{r['bound_s']:.4f}s",
            f"dom={r['dominant']};compute={r['compute_s']:.4f};"
            f"memory={r['memory_s']:.4f};coll={r['collective_s']:.4f};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"peakGB={r['memory']['peak_bytes'] / 1e9:.1f}",
        ])
    emit(rows, ["name", "bound", "derived"], "roofline")
    return rows


if __name__ == "__main__":
    main()
