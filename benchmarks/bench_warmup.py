"""Table 5 / Appendix C: warmup priors vs Tabula Rasa vs Random.

Cumulative regret vs the per-prompt oracle over the test split, per
budget regime, with R@200, per-seed std, catastrophic-failure counts
(regret > 2x pooled median) and an exact binomial sign test.
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import (
    BUDGETS, SEEDS, benchmark, bootstrap_ci, emit, run_condition,
)
from repro.core import evaluate
from repro.core.types import RouterConfig


def sign_test(wins: int, n: int) -> float:
    """Exact two-sided binomial sign test p-value."""
    p = sum(math.comb(n, k) for k in range(wins, n + 1)) / 2 ** n
    return min(1.0, 2 * min(p, 1 - p + math.comb(n, wins) / 2 ** n))


def random_baseline(env, seeds):
    rng_regrets = []
    oracle = env.rewards.max(axis=1)
    for s in seeds:
        rng = np.random.default_rng(s)
        arms = rng.integers(0, env.k, env.n)
        r = env.rewards[np.arange(env.n), arms]
        rng_regrets.append((oracle - r).sum())
    return np.asarray(rng_regrets)


def main(seeds=SEEDS):
    b = benchmark()
    env = b.test
    rows = []
    regimes = dict(BUDGETS)
    regimes["none"] = 1.0
    for rname, budget in regimes.items():
        res_w = run_condition("pareto", env, budget, seeds=seeds)
        res_t = run_condition("tabula_rasa", env, budget, seeds=seeds)
        # per-seed regret needs the per-seed prompt order: recompute with
        # the same seed permutations used inside evaluate.run
        reg_w, reg_t = [], []
        oracle = env.rewards.max(axis=1)
        for i, s in enumerate(seeds):
            perm = np.random.default_rng(int(s)).permutation(env.n)
            reg_w.append((oracle[perm] - res_w.rewards[i]).sum())
            reg_t.append((oracle[perm] - res_t.rewards[i]).sum())
        reg_w = np.asarray(reg_w)
        reg_t = np.asarray(reg_t)
        r200_w = np.asarray([
            (oracle[np.random.default_rng(int(s)).permutation(env.n)][:200]
             - res_w.rewards[i][:200]).sum() for i, s in enumerate(seeds)])
        r200_t = np.asarray([
            (oracle[np.random.default_rng(int(s)).permutation(env.n)][:200]
             - res_t.rewards[i][:200]).sum() for i, s in enumerate(seeds)])
        pooled = np.median(np.concatenate([reg_w, reg_t]))
        cat_w = int((reg_w > 2 * pooled).sum())
        cat_t = int((reg_t > 2 * pooled).sum())
        wins = int((reg_w < reg_t).sum())
        p = sign_test(wins, len(seeds))
        m_w, lo_w, hi_w = bootstrap_ci(reg_w)
        m_t, lo_t, hi_t = bootstrap_ci(reg_t)
        rows.append([
            f"warmup_{rname}", f"{m_w:.1f}",
            f"ci=[{lo_w:.1f},{hi_w:.1f}];std={reg_w.std():.1f};"
            f"r200={r200_w.mean():.1f};cat={cat_w}/{len(seeds)}"])
        rows.append([
            f"tabula_rasa_{rname}", f"{m_t:.1f}",
            f"ci=[{lo_t:.1f},{hi_t:.1f}];std={reg_t.std():.1f};"
            f"r200={r200_t.mean():.1f};cat={cat_t}/{len(seeds)};"
            f"warmup_wins={wins}/{len(seeds)};p_sign={p:.4f}"])
        if rname == "none":
            rr = random_baseline(env, seeds)
            m, lo, hi = bootstrap_ci(rr)
            rows.append(["random_none", f"{m:.1f}", f"ci=[{lo:.1f},{hi:.1f}]"])
    emit(rows, ["name", "regret", "derived"], "warmup")
    return rows


if __name__ == "__main__":
    main()
